//! **Ablation A14**: the multi-tenant fabric — fairness under symmetric
//! contention, bounded straggler damage, and contention-aware selection
//! beating the quiet-fabric table under saturating background traffic.
//!
//! The paper's scaling numbers assume a quiet fabric; arXiv 1609.06870's
//! survey shows shared Cloud/HPC fabrics are anything but. The
//! observable contract this bench ASSERTS:
//!
//! * **fair sharing** — two identical colocated tenants time-sharing one
//!   fabric split the egress wires near-evenly: Jain's index over their
//!   per-tenant busy time >= 0.9 (strict-priority rails have no
//!   starvation mode for same-priority peers);
//! * **no straggler cascade** — one node computing 2x slower stretches
//!   the synchronous iteration by AT MOST ~2x (the straggler's own
//!   factor): lockstep waits expose the slowdown, they never amplify it;
//! * **contention-aware wins under load** — a tuning table measured on
//!   the QUIET fabric mis-ranks algorithms once saturating background
//!   flows stall every round; the contention-aware pick (derated-fabric
//!   re-rank from OBSERVED utilization) strictly beats the quiet-table
//!   pick when both are timed under the same background load.
//!
//! Emits `BENCH_multitenant.json` (repo root).
//!
//! Run: `cargo bench --bench a14_multitenant`

use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::simexec::SimCollectives;
use mlsl::collectives::WireDtype;
use mlsl::engine::{simulate, simulate_tenants, CommMode, EngineConfig, TenantSpec};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::{BgFlow, BgPlan, NetSim, StragglerPlan};
use mlsl::metrics::print_table;
use mlsl::models::ModelDesc;
use mlsl::trace::Utilization;
use mlsl::tuner::{tune, Contention, ProbeSpec, SelectionPolicy};

const P: usize = 8;

fn engine_cfg(p: usize) -> EngineConfig {
    let mut cfg = EngineConfig::new(
        ModelDesc::by_name("resnet50").expect("model exists"),
        Topology::eth_10g(),
        p,
    );
    cfg.mode = CommMode::BulkSync;
    cfg.iterations = 2;
    cfg
}

/// Saturating same-priority background: every node streams 512 KiB to
/// its neighbor on a period matching the service time, so the NICs are
/// ~100% busy for `horizon_ns` and every collective round queues.
fn saturating_bg(p: usize, horizon_ns: u64) -> BgPlan {
    let bytes: u64 = 512 << 10;
    let period_ns = 420_000; // ~512 KiB / 1.25 GB/s, back to back
    let reps = (horizon_ns / period_ns + 1).min(10_000) as u32;
    let flows = (0..p)
        .map(|src| BgFlow {
            src,
            dst: (src + 1) % p,
            bytes,
            start_ns: 0,
            period_ns,
            reps,
            priority: 1,
        })
        .collect();
    BgPlan { seed: 0, flows }
}

/// Time one allreduce (max rank-completion ns) under a background plan.
fn time_under_bg(
    topo: &Topology,
    alg: mlsl::collectives::Algorithm,
    elems: usize,
    bg: &BgPlan,
) -> u64 {
    let progs = build(CollectiveKind::Allreduce, alg, P, elems).expect("legal algorithm");
    let mut sim = NetSim::new(topo.clone(), P);
    sim.set_background(bg.clone());
    let mut exec = SimCollectives::new();
    let mut completions = exec.post(&mut sim, 1, progs, WireDtype::F32, 1);
    while exec.in_flight() > 0 {
        let ev = sim.next().expect("deadlock under background");
        exec.on_event_into(&mut sim, &ev, &mut completions);
    }
    completions.iter().map(|c| c.at).max().expect("ranks completed")
}

fn main() {
    let topo = Topology::eth_10g();

    // -- claim 1: two symmetric colocated tenants share fairly ----------
    let cfg = engine_cfg(4);
    let single = simulate(cfg.clone());
    let two = simulate_tenants(&cfg, &TenantSpec { jobs: 2, disjoint: false }, false);
    println!("{}", two.fairness_line());
    let mut rows = vec![vec![
        "1 (alone)".to_string(),
        format!("{:.2}", single.iter_ns as f64 / 1e6),
        "1.000".to_string(),
    ]];
    for (t, r) in two.reports.iter().enumerate() {
        rows.push(vec![
            format!("2, tenant {t}"),
            format!("{:.2}", r.iter_ns as f64 / 1e6),
            format!("{:.3}", r.iter_ns as f64 / single.iter_ns as f64),
        ]);
    }
    print_table(
        "A14: colocated tenants on eth10g p=4 (resnet50, bulk)",
        &["tenants", "iter ms", "vs alone"],
        &rows,
    );
    assert!(
        two.jain >= 0.9,
        "symmetric tenants must share near-evenly: jain = {:.3} ({:?} busy shares)",
        two.jain,
        two.egress_share
    );
    for r in &two.reports {
        assert!(
            r.iter_ns > single.iter_ns,
            "sharing a fabric must cost something: {} vs alone {}",
            r.iter_ns,
            single.iter_ns
        );
    }

    // -- claim 2: a 2x straggler is bounded by its own factor -----------
    let healthy = simulate(engine_cfg(4));
    let mut cfg = engine_cfg(4);
    cfg.straggler = Some(StragglerPlan::parse("0:2.0", 4).expect("valid spec"));
    let straggled = simulate(cfg);
    let ratio = straggled.iter_ns as f64 / healthy.iter_ns as f64;
    println!(
        "\nstraggler: healthy {:.2} ms -> one 2x straggler {:.2} ms ({ratio:.2}x, \
         report max {:.2}x)",
        healthy.iter_ns as f64 / 1e6,
        straggled.iter_ns as f64 / 1e6,
        straggled.straggler_max_milli as f64 / 1000.0,
    );
    assert_eq!(straggled.straggler_max_milli, 2000, "report must surface the factor");
    assert!(ratio > 1.0, "a 2x straggler must slow the lockstep iteration");
    assert!(
        ratio <= 2.05,
        "straggler damage must not cascade past its own factor: {ratio:.3}x"
    );

    // -- claim 3: contention-aware beats the quiet table under load -----
    // Measure a quiet-fabric tuning table at p=8 …
    let mut spec = ProbeSpec::quick();
    spec.max_ranks = P;
    let table = tune(&topo, &spec);
    let policy = SelectionPolicy::Tuned(table);
    // … observe utilization under saturating background (one allreduce
    // riding the loaded fabric, traced), exactly as the engine's
    // contention-aware mode does …
    let bg = saturating_bg(P, 60_000_000);
    let contention = {
        let progs = build(CollectiveKind::Allreduce, mlsl::collectives::Algorithm::Ring, P, 1 << 18)
            .expect("ring builds");
        let mut sim = NetSim::new(topo.clone(), P);
        sim.set_background(bg.clone());
        sim.set_trace(true);
        let mut exec = SimCollectives::new();
        let mut completions = exec.post(&mut sim, 1, progs, WireDtype::F32, 1);
        while exec.in_flight() > 0 {
            let ev = sim.next().expect("deadlock in utilization probe");
            exec.on_event_into(&mut sim, &ev, &mut completions);
        }
        let trace = sim.take_trace().expect("tracing was on").normalized();
        let u = Utilization::compute(&trace, P, 1, sim.now().max(1));
        Contention::from_utilization(&u, &topo)
    };
    assert!(
        !contention.is_quiet(),
        "saturating background must register as observed contention: {contention:?}"
    );
    println!(
        "\nobserved contention under saturating bg: avail {:?} milli",
        contention.avail_milli
    );

    // … scan sizes for one where the quiet table and the contention
    // correction disagree, then time BOTH picks under the same load.
    let members: Vec<usize> = (0..P).collect();
    let menu = [WireDtype::F32];
    let mut flip = None;
    let mut pick_rows = Vec::new();
    for kb in [64u64, 128, 256, 384, 512, 768, 1024, 2048] {
        let bytes = kb << 10;
        let (quiet_pick, _) = policy.choose_for_members_wire(
            &topo,
            &members,
            CollectiveKind::Allreduce,
            bytes,
            &menu,
            1000,
        );
        let (aware_pick, _) = policy.choose_for_members_wire_contended(
            &topo,
            &members,
            CollectiveKind::Allreduce,
            bytes,
            &menu,
            1000,
            Some(&contention),
        );
        pick_rows.push(vec![
            format!("{kb} KiB"),
            quiet_pick.to_string(),
            aware_pick.to_string(),
        ]);
        if quiet_pick != aware_pick && flip.is_none() {
            flip = Some((bytes, quiet_pick, aware_pick));
        }
    }
    print_table(
        &format!("A14: allreduce picks at p={P}, eth10g (quiet table vs contention-aware)"),
        &["bytes/rank", "quiet-table pick", "contention-aware pick"],
        &pick_rows,
    );
    let (bytes, quiet_pick, aware_pick) =
        flip.expect("contention must re-rank at least one scanned size");
    let quiet_t = time_under_bg(&topo, quiet_pick, (bytes / 4) as usize, &bg);
    let aware_t = time_under_bg(&topo, aware_pick, (bytes / 4) as usize, &bg);
    let speedup = quiet_t as f64 / aware_t as f64;
    println!(
        "\nunder saturating bg at {} KiB/rank: quiet-table {quiet_pick} {:.2} ms vs \
         contention-aware {aware_pick} {:.2} ms ({speedup:.2}x)",
        bytes >> 10,
        quiet_t as f64 / 1e6,
        aware_t as f64 / 1e6,
    );
    assert!(
        aware_t < quiet_t,
        "the contention-aware pick must strictly beat the quiet-table pick under \
         the load that motivated it: {aware_pick} {aware_t} ns vs {quiet_pick} {quiet_t} ns"
    );

    // -- emit BENCH_multitenant.json at the repo root -------------------
    let json = format!(
        "{{\n  \"bench\": \"a14_multitenant\",\n  \"topology\": \"{}\",\n\
         \"jain_two_tenants\": {:.4},\n  \"tenant_iter_ns\": [{}, {}],\n\
         \"single_iter_ns\": {},\n\
         \"straggler_factor\": 2.0,\n  \"straggler_ratio\": {:.4},\n\
         \"contention_avail_milli\": {:?},\n\
         \"flip_bytes\": {},\n  \"quiet_pick\": \"{}\",\n  \"aware_pick\": \"{}\",\n\
         \"quiet_pick_ns\": {},\n  \"aware_pick_ns\": {},\n  \"aware_speedup\": {:.4}\n}}\n",
        topo.name,
        two.jain,
        two.reports[0].iter_ns,
        two.reports[1].iter_ns,
        single.iter_ns,
        ratio,
        contention.avail_milli,
        bytes,
        quiet_pick,
        aware_pick,
        quiet_t,
        aware_t,
        speedup,
    );
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_multitenant.json");
    std::fs::write(out, &json).expect("write BENCH_multitenant.json");
    println!("wrote {out}");

    println!("\nexpected shape: two identical tenants halve the fabric (Jain ~1.0) and each");
    println!("iteration stretches; a lone 2x straggler costs at most its own factor because");
    println!("lockstep sync waits, it does not amplify. Under saturating background the");
    println!("quiet-measured table still ranks by quiet-fabric wire time, but every round");
    println!("now pays a queueing stall — the observed-utilization re-rank trades wire");
    println!("efficiency for fewer rounds and wins back the difference. OK");
}

//! Cluster substrate: the paper's testbeds, rebuilt.
//!
//! The paper evaluates MLSL on Xeon/Omnipath (Fig. 2, up to 256 nodes) and
//! Xeon/10GbE (the 1.8–2.2× prioritization claim). We do not have those
//! clusters; per DESIGN.md §Substitutions this module provides:
//!
//! * [`sim`] — a discrete-event network simulator whose NICs are
//!   strict-priority, *preemptive* servers: a higher-priority message takes
//!   the wire from an in-flight bulk transfer, which is exactly the
//!   mechanism MLSL's message prioritization needs and MPI lacks.
//! * [`shm`] — a real in-process fabric (ranks = threads, wires = lock-free
//!   channels) used by the *real* training path, so the identical
//!   collectives/progress code runs with actual gradient bytes.
//! * [`topology`] — parameter presets for the two fabrics the paper uses
//!   plus the node compute model (Skylake-class FLOPs).
//!
//! # Two-tier fabric model
//!
//! Real clusters run several ranks per node: a [`Topology`] therefore
//! carries TWO parameter sets — the inter-node tier (NIC line rate,
//! switch latency, injection overhead) and an intra-node shared-memory
//! tier — plus `ranks_per_node` with contiguous grouping (`node = rank /
//! ranks_per_node`). The simulator prices every hop at its tier:
//! `src`/`dst` on the same node serialize at `intra_gbps` and pay
//! `intra_latency_ns`, everything else uses the NIC parameters. The
//! `-x<r>` preset suffixes (`eth10g-x2`, `opa-x4`) select the paper's
//! testbeds at r ranks/node; `ranks_per_node == 1` collapses to the old
//! flat model, bit-for-bit. Hierarchical collectives
//! ([`crate::collectives::Algorithm::Hierarchical`]) exploit the fast
//! tier by reducing onto one leader per node before touching the wire.

pub mod event;
pub mod shm;
pub mod sim;
pub mod topology;

pub use sim::{NetSim, SimEvent};
pub use topology::{NodeSpec, Topology};

use crate::{Ns, Priority, Rank};

/// A point-to-point message descriptor (what traverses the simulated wire).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MsgDesc {
    pub src: Rank,
    pub dst: Rank,
    pub bytes: u64,
    pub priority: Priority,
    /// Opaque tag the layer above uses to route completions
    /// (collective id << 32 | step index, by convention).
    pub tag: u64,
}

/// Gigabytes-per-second → bytes-per-nanosecond.
pub fn gbps_to_bytes_per_ns(gbps: f64) -> f64 {
    // 1 Gbit/s = 1e9 bit/s = 0.125e9 byte/s = 0.125 byte/ns.
    gbps * 0.125
}

/// Transfer duration in ns for `bytes` at `gbps` line rate.
pub fn wire_ns(bytes: u64, gbps: f64) -> Ns {
    let bpns = gbps_to_bytes_per_ns(gbps);
    (bytes as f64 / bpns).ceil() as Ns
}

"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels
from compile.kernels import ref

RNG = np.random.default_rng(0)


def randf(*shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (32, 64, 48), (128, 128, 128),
                                   (256, 128, 512), (5, 7, 3)])
@pytest.mark.parametrize("act", ["none", "gelu", "relu"])
def test_matmul_bias_act(m, k, n, act):
    x, w, b = randf(m, k), randf(k, n), randf(n)
    got = kernels.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_activation():
    with pytest.raises(ValueError):
        kernels.matmul_bias_act(randf(4, 4), randf(4, 4), randf(4), "tanh")


def test_matmul_accumulates_f32():
    # bf16-representable inputs whose product needs f32 accumulation.
    x = jnp.full((16, 512), 0.01, jnp.float32)
    w = jnp.full((512, 16), 0.01, jnp.float32)
    b = jnp.zeros((16,), jnp.float32)
    got = kernels.matmul_bias_act(x, w, b, "none")
    np.testing.assert_allclose(got, jnp.full((16, 16), 512 * 1e-4), rtol=1e-5)


def test_matmul_tile_invariance():
    # Different tilings must give identical results.
    x, w, b = randf(64, 96), randf(96, 64), randf(64)
    a = kernels.matmul_bias_act(x, w, b, "gelu", bm=16, bn=16, bk=32)
    c = kernels.matmul_bias_act(x, w, b, "gelu", bm=64, bn=64, bk=96)
    # f32 accumulation order differs across K tilings -> small drift.
    np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,s,d", [(1, 1, 8, 8), (2, 4, 32, 16),
                                     (1, 2, 128, 64), (3, 1, 17, 5)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention(b, h, s, d, causal):
    q, k, v = randf(b, h, s, d), randf(b, h, s, d), randf(b, h, s, d)
    got = kernels.attention(q, k, v, causal)
    want = ref.attention(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_causal_masks_future():
    # Output at position 0 must ignore later positions entirely.
    b, h, s, d = 1, 1, 16, 8
    q, k, v = randf(b, h, s, d), randf(b, h, s, d), randf(b, h, s, d)
    base = kernels.attention(q, k, v, True)
    v2 = v.at[:, :, 1:, :].set(randf(b, h, s - 1, d))
    pert = kernels.attention(q, k, v2, True)
    np.testing.assert_allclose(base[:, :, 0], pert[:, :, 0], rtol=1e-6)


def test_attention_rows_sum_property():
    # With v = ones, attention output is exactly ones (probs sum to 1).
    b, h, s, d = 2, 2, 32, 16
    q, k = randf(b, h, s, d), randf(b, h, s, d)
    v = jnp.ones((b, h, s, d), jnp.float32)
    out = kernels.attention(q, k, v, True)
    np.testing.assert_allclose(out, jnp.ones_like(out), rtol=1e-5)


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nblk", [1, 3, 64, 257])
def test_quantize_matches_ref(nblk):
    x = randf(nblk * ref.QBLOCK)
    q_got, s_got = kernels.quantize_int8(x)
    q_want, s_want = ref.quantize_int8(x)
    np.testing.assert_array_equal(np.asarray(q_got), np.asarray(q_want))
    np.testing.assert_allclose(s_got, s_want, rtol=1e-6)


def test_quantize_roundtrip_error_bound():
    x = 10.0 * randf(64 * ref.QBLOCK)
    q, s = kernels.quantize_int8(x)
    deq = kernels.dequantize_int8(q, s)
    # Error bounded by half a quantization step per block.
    blocks = np.asarray(x).reshape(-1, ref.QBLOCK)
    step = np.abs(blocks).max(axis=1) / 127.0
    err = np.abs(np.asarray(deq).reshape(-1, ref.QBLOCK) - blocks)
    assert (err <= 0.5 * step[:, None] + 1e-6).all()


def test_quantize_zero_block():
    x = jnp.zeros((2 * ref.QBLOCK,), jnp.float32)
    q, s = kernels.quantize_int8(x)
    assert np.asarray(q).max() == 0 and np.asarray(q).min() == 0
    deq = kernels.dequantize_int8(q, s)
    np.testing.assert_array_equal(np.asarray(deq), np.zeros_like(deq))


def test_quantize_preserves_sign_and_max():
    x = randf(ref.QBLOCK)
    q, s = kernels.quantize_int8(x)
    qa = np.asarray(q, np.int32)
    xa = np.asarray(x)
    i = np.abs(xa).argmax()
    assert abs(qa[i]) == 127
    nz = np.abs(xa) > np.abs(xa).max() / 254  # above half-step: sign survives
    assert (np.sign(qa[nz]) == np.sign(xa[nz])).all()


# ---------------------------------------------------------------------------
# sgd_momentum
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(17,), (128, 64), (3, 5, 7), (4096,), (5000,)])
def test_sgd_momentum(shape):
    w, m, g = randf(*shape), randf(*shape), randf(*shape)
    wn, mn = kernels.sgd_momentum(w, m, g, lr=0.1, mu=0.9, wd=1e-4)
    we, me = ref.sgd_momentum(w, m, g, 0.1, 0.9, 1e-4)
    np.testing.assert_allclose(wn, we, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(mn, me, rtol=1e-6, atol=1e-6)


def test_sgd_zero_grad_pure_momentum():
    w, m = randf(64), randf(64)
    g = jnp.zeros((64,), jnp.float32)
    wn, mn = kernels.sgd_momentum(w, m, g, lr=1.0, mu=0.5, wd=0.0)
    np.testing.assert_allclose(mn, 0.5 * m, rtol=1e-6)
    np.testing.assert_allclose(wn, w - 0.5 * m, rtol=1e-6)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(4, 64), (2, 32, 128), (1, 256), (7, 48)])
def test_layernorm(shape):
    x = randf(*shape)
    g, b = randf(shape[-1]), randf(shape[-1])
    got = kernels.layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_layernorm_output_stats():
    x = 5.0 + 3.0 * randf(16, 256)
    out = kernels.layernorm(x, jnp.ones((256,)), jnp.zeros((256,)))
    np.testing.assert_allclose(np.asarray(out).mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out).std(axis=-1), 1.0, atol=1e-2)

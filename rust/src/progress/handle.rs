//! Non-blocking completion handles for submitted collectives.

use std::sync::mpsc::{Receiver, TryRecvError};

/// Completion handle: redeem for the reduced buffer.
pub struct Handle {
    pub(crate) rx: Receiver<Vec<f32>>,
    pub(crate) coll_id: u64,
}

impl Handle {
    /// Block until the collective completes; returns the result buffer.
    pub fn wait(self) -> Vec<f32> {
        self.rx
            .recv()
            .unwrap_or_else(|_| panic!("comm core died before op {} completed", self.coll_id))
    }

    /// Non-blocking poll; `Some(buf)` exactly once when complete.
    pub fn try_wait(&mut self) -> Option<Vec<f32>> {
        match self.rx.try_recv() {
            Ok(buf) => Some(buf),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                panic!("comm core died before op {} completed", self.coll_id)
            }
        }
    }

    pub fn id(&self) -> u64 {
        self.coll_id
    }
}

//! Discrete-event network simulator with strict-priority, preemptive NICs.
//!
//! Model (DESIGN.md §Key-design-decisions):
//!
//! * Each node owns an egress NIC serializing at the topology line rate.
//!   Among queued transfers the one with the lowest `(priority, seq)`
//!   holds the wire — so a newly-posted *urgent* message **preempts** an
//!   in-flight bulk transfer exactly the way the paper's message
//!   prioritization preempts "an ongoing large weight gradient exchange".
//!   Preempted transfers keep their progress and resume when the wire
//!   frees up (chunk-exact resume is provided by the collectives layer,
//!   byte-exact resume inside a chunk by this NIC model).
//! * A transfer costs `per_msg_overhead + bytes/bw` on the egress wire,
//!   then `latency` in flight; receive side is not a contention point
//!   (receiver-driven contention is secondary for allreduce patterns where
//!   each rank receives from exactly one peer per step).
//! * Egress can be *gated* per node: with `comm_gated = true` nothing
//!   progresses — this models plain MPI non-blocking collectives without
//!   an async progress thread (communication only advances inside
//!   blocking MPI calls), the out-of-box Horovod behaviour of claim C2.
//! * **Topology-aware priorities**: urgency classes exist only on the
//!   contended NIC tiers. Hops whose deepest common tier is a
//!   shared-memory tier bypass the NIC priority queue entirely — each
//!   rank additionally owns a shm egress channel (mirroring the per-rank
//!   NIC egress model) where its intra copies serialize in plain FIFO
//!   order, one free class. An "urgent" intra copy can neither preempt
//!   nor be delayed by NIC traffic: shared-memory copies never cross the
//!   NIC. In-rack and cross-rack hops both ride the NIC (priced at their
//!   own tier's rate/latency) and contend under strict priority there.
//! * **Multi-rail NICs**: each node owns [`Topology::max_rails`]
//!   independent egress rails, each serializing at the per-rail line
//!   rate with its own strict-priority queue, generation counter and
//!   busy accounting. A transfer is striped into
//!   [`Topology::stripe_count`] chunk pieces, piece `i` riding rail
//!   `(i + src) % rails` — a pure assignment, so resume/replay stays
//!   byte-identical. Bandwidth-bound transfers occupy every rail
//!   (aggregate injection bandwidth scales with the rail count);
//!   latency-bound sub-chunk messages ride one rail and pay one
//!   overhead. Delivery fires `latency` after the LAST piece leaves the
//!   wire.
//!
//! * **Chaos mode**: a seeded [`ChaosPlan`] (driven by
//!   [`crate::util::prng`]) injects link flaps (tier-level latency
//!   spikes and temporary zero-bandwidth windows), dead NIC rails
//!   (striping re-routes over the surviving rails with the same
//!   `(chunk + src) % rails` assignment, queued pieces migrate
//!   mid-transfer without losing banked progress) and per-node compute
//!   slowdown factors. Every fault is scheduled from the plan alone, so
//!   the same seed yields a byte-identical event stream — faults bend
//!   *timing*, never payloads.
//! * **Multi-tenant mode**: transfers carry a tenant id recovered from
//!   the message tag ([`tenant_of_tag`]) — per-tenant collectives run in
//!   disjoint tag spaces (tenant in bits [`TENANT_TAG_SHIFT`]..63) and
//!   background flows set the [`BG_TAG`] bit. Tenants share the
//!   strict-priority egress rails with no reservations (contention is
//!   the point), while [`SimStats`] splits bytes/messages/wire-busy per
//!   tenant so fairness metrics (egress share, Jain's index) fall out of
//!   the accounting. A seeded [`BgPlan`] injects deterministic
//!   background flows (same one-seed/byte-identical contract as
//!   [`ChaosPlan`]) and a [`StragglerPlan`] pins *persistent* per-node
//!   compute slowdowns — distinct from chaos's transient windows and
//!   composing multiplicatively with them.
//! * **Partitioned mode** ([`super::par`]): a `NetSim` can be built as
//!   one shard of a node-partitioned fleet
//!   ([`NetSim::new_partition`]). A shard silently ignores work posted
//!   for ranks it does not own and, when a message's destination lives
//!   on another shard, emits [`super::par::Mail`] into an outbox
//!   ([`NetSim::take_mail`]) instead of scheduling local delivery; the
//!   coordinator routes mail at conservative-lookahead window
//!   boundaries ([`crate::collectives::parexec`]). Every
//!   cross-partition hop rides a NIC tier and therefore pays at least
//!   [`Topology::lookahead_ns`] of in-flight latency — the lower bound
//!   that makes windowed execution exact.
//!
//! The simulator is deterministic: equal-time events fire in issue order.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use super::event::EventQueue;
use super::par::{shard_of, Mail};
use super::topology::Topology;
use super::MsgDesc;
use crate::trace::{BusySpan, Cause, ComputeSpan, Trace, TraceBuf, TraceEvent, TrackChan};
use crate::util::prng::Prng;
use crate::{Ns, Priority, Rank};

/// Externally visible simulation events.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimEvent {
    /// `msg` fully arrived at `msg.dst`.
    MsgDelivered { msg: MsgDesc, at: Ns },
    /// A compute timer posted with [`NetSim::compute`] expired.
    ComputeDone { node: Rank, tag: u64, at: Ns },
}

/// Which egress channel of a node a transfer serializes on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Chan {
    /// One NIC rail: strict-priority, preemptive — the contended tier.
    Inter { rail: u32 },
    /// The intra-node shared-memory channel: priority-free FIFO.
    Shm,
}

#[derive(Debug)]
enum Internal {
    /// Candidate egress completion for (node, chan, xfer); validated by
    /// the channel's generation counter.
    EgressDone { node: Rank, chan: Chan, xfer: u64, gen: u64 },
    Deliver { msg_id: u64 },
    ComputeDone { node: Rank, tag: u64 },
    /// A zero-bandwidth flap window opens (`on`) or closes (`!on`).
    ChaosGate { on: bool },
    /// Scheduled death of `plan.rail_deaths[idx]`.
    RailDie { idx: usize },
    /// Repetition `rep` of background flow `flow` enters the fabric.
    BgInject { flow: u32, rep: u32 },
}

struct Transfer {
    msg_id: u64,
    /// Remaining egress time (overhead + wire) at `checkpoint`.
    remaining_ns: Ns,
    checkpoint: Ns,
    running: bool,
    /// Urgency class the piece was enqueued under — carried so a
    /// rail-death migration can re-enqueue it at the same priority.
    class: Priority,
    /// Owning tenant (accounting slot) — 0 outside multi-tenant mode.
    tenant: u16,
}

/// Per-NIC egress queue. Transfers live in `slab`; `order` is a
/// strict-priority min-heap of (priority, id) — O(log n) per event
/// instead of the O(n) scan a Vec would need (perf_micro: the simulator
/// event loop is the L3 hot path; see EXPERIMENTS.md §Perf).
#[derive(Default)]
struct Nic {
    slab: HashMap<u64, Transfer>,
    order: BinaryHeap<Reverse<(Priority, u64)>>,
    gated: bool,
    /// Gated by an active zero-bandwidth chaos window — kept separate
    /// from the engine-driven `gated` flag so fault injection and
    /// MPI-style progress gating compose without clobbering each other.
    chaos_gated: bool,
    /// Rail killed by a [`ChaosPlan`]: never serves traffic again.
    dead: bool,
    /// Generation counter invalidating stale EgressDone events.
    gen: u64,
    /// Total ns the wire was busy (for utilization metrics).
    busy_ns: Ns,
    busy_since: Option<Ns>,
    /// Currently-running transfer id (the head when not gated).
    running: Option<u64>,
}

impl Nic {
    /// Highest-priority live transfer id (lazily dropping stale entries).
    fn head(&mut self) -> Option<u64> {
        while let Some(Reverse((_, id))) = self.order.peek() {
            if self.slab.contains_key(id) {
                return Some(*id);
            }
            self.order.pop();
        }
        None
    }
}

/// Aggregate traffic statistics, per priority class.
#[derive(Debug, Clone)]
pub struct SimStats {
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    /// Bytes per priority class, indexed directly by the `u8` class —
    /// a fixed-size array instead of a `HashMap` keeps the per-send
    /// accounting branch- and alloc-free on the event-loop hot path.
    pub bytes_by_priority: [u64; 256],
    pub preemptions: u64,
    /// Bytes sent per tenant, slot `n_tenants` = background traffic.
    /// Empty until [`NetSim::set_tenants`] — single-tenant runs pay
    /// nothing for the multi-tenant accounting.
    pub tenant_bytes: Vec<u64>,
    /// Messages sent per tenant (same slot layout as `tenant_bytes`).
    pub tenant_msgs: Vec<u64>,
    /// Egress-wire busy ns attributed per tenant (summed over every
    /// rail and the shm channels; same slot layout as `tenant_bytes`).
    pub tenant_busy_ns: Vec<u64>,
}

impl Default for SimStats {
    fn default() -> Self {
        Self {
            msgs_sent: 0,
            bytes_sent: 0,
            bytes_by_priority: [0; 256],
            preemptions: 0,
            tenant_bytes: Vec::new(),
            tenant_msgs: Vec::new(),
            tenant_busy_ns: Vec::new(),
        }
    }
}

// ---------------------------------------------------------------------------
// Chaos mode: seeded fault injection
// ---------------------------------------------------------------------------

/// One link-flap window on a NIC tier. A zero-bandwidth flap gates every
/// NIC rail fleet-wide for the window (the blast radius of a switch
/// brown-out: nothing injects until it clears); a latency flap multiplies
/// the in-flight latency of messages whose deepest common tier is
/// `level`, applied when delivery is scheduled. Multipliers are integer
/// milli-units (1000 = healthy) so replay comparisons stay exact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlapWindow {
    /// NIC tier the flap lives on (never a shared-memory level).
    pub level: usize,
    /// Window [from, until) in sim ns.
    pub from: Ns,
    pub until: Ns,
    /// true → zero-bandwidth window; false → latency spike only.
    pub zero_bw: bool,
    /// Latency multiplier in milli-units (1000 = unchanged).
    pub latency_mult_milli: u64,
}

/// Scheduled death of one NIC egress rail. From `at` on, the rail serves
/// nothing: its queued pieces migrate to the surviving rails (banked
/// progress preserved) and new transfers stripe over survivors only.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RailDeath {
    pub node: Rank,
    pub rail: u32,
    pub at: Ns,
}

/// A seeded fault-injection schedule. Everything is derived from the
/// seed up front — [`NetSim`] consumes the plan as pure data, so two
/// runs with the same plan (hence the same seed) produce byte-identical
/// event streams. Faults bend timing only; payloads are never corrupted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosPlan {
    pub seed: u64,
    pub flaps: Vec<FlapWindow>,
    pub rail_deaths: Vec<RailDeath>,
    /// Per-node compute slowdown in milli-units (1000 = healthy). A
    /// straggler at 2500 takes 2.5× the healthy compute time.
    pub slowdown_milli: Vec<u64>,
}

impl ChaosPlan {
    /// A quiet plan (no faults) — useful as a baseline in tests.
    pub fn quiet(seed: u64, p: usize) -> Self {
        Self { seed, flaps: Vec::new(), rail_deaths: Vec::new(), slowdown_milli: vec![1000; p] }
    }

    /// Derive a full fault schedule from `seed` for a `p`-rank run of
    /// roughly `horizon_ns`: 1–3 link flaps on NIC tiers (a third of
    /// them zero-bandwidth, the rest 2–10× latency spikes), up to one
    /// rail death per surviving-rail margin on multi-rail fabrics
    /// (never a node's last rail), and a handful of node slowdowns
    /// (1.1–2.5×). Deterministic in its arguments.
    pub fn generate(seed: u64, topo: &Topology, p: usize, horizon_ns: Ns) -> Self {
        let mut r = Prng::seed(seed);
        let horizon = horizon_ns.max(1000);
        let nic_levels = topo.nic_levels();
        let mut flaps = Vec::new();
        if !nic_levels.is_empty() {
            for _ in 0..1 + r.below(3) {
                let level = nic_levels[r.usize_below(nic_levels.len())];
                let from = r.below(horizon * 3 / 4);
                let dur = horizon / 20 + r.below((horizon / 10).max(1));
                let zero_bw = r.below(3) == 0;
                let latency_mult_milli = if zero_bw { 1000 } else { 2000 + r.below(8001) };
                flaps.push(FlapWindow {
                    level,
                    from,
                    until: from + dur,
                    zero_bw,
                    latency_mult_milli,
                });
            }
        }
        let rails = topo.max_rails();
        let mut rail_deaths: Vec<RailDeath> = Vec::new();
        if rails > 1 && p > 0 {
            let kills = 1 + r.below(rails.min(3) as u64 - 1);
            for _ in 0..kills {
                let node = r.usize_below(p);
                let rail = r.below(rails as u64) as u32;
                let at = horizon / 4 + r.below(horizon / 2);
                let already = rail_deaths.iter().filter(|d| d.node == node).count() as u32;
                let dup = rail_deaths.iter().any(|d| d.node == node && d.rail == rail);
                // Never schedule a node's last rail to die.
                if !dup && already + 1 < rails {
                    rail_deaths.push(RailDeath { node, rail, at });
                }
            }
        }
        let mut slowdown_milli = vec![1000u64; p];
        if p > 0 {
            for _ in 0..1 + r.below((p as u64 / 8).max(1)) {
                let node = r.usize_below(p);
                slowdown_milli[node] = 1100 + r.below(1401); // 1.1–2.5×
            }
        }
        Self { seed, flaps, rail_deaths, slowdown_milli }
    }

    /// Latency multiplier active at `now` for tier `level` (milli-units;
    /// overlapping spikes compound).
    fn latency_mult_at(&self, level: usize, now: Ns) -> u64 {
        let mut m = 1000u64;
        for f in &self.flaps {
            if !f.zero_bw && f.level == level && f.from <= now && now < f.until {
                m = m.saturating_mul(f.latency_mult_milli) / 1000;
            }
        }
        m
    }
}

/// Counters for faults actually applied during a run (all driven purely
/// by the plan, so deterministic under a seed).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosStats {
    pub zero_bw_windows: u64,
    pub latency_spikes: u64,
    pub rails_killed: u64,
    /// Queued egress pieces migrated off a dying rail mid-transfer.
    pub transfers_rerouted: u64,
    /// Compute timers stretched by a per-node slowdown factor.
    pub slowdowns_applied: u64,
}

// ---------------------------------------------------------------------------
// Multi-tenant mode: tenant tag spaces, background traffic, stragglers
// ---------------------------------------------------------------------------

/// Bit 63 of a message tag marks background-injector traffic. Collective
/// executors key operations on the full tag, so background messages can
/// never collide with (or be mistaken for) a collective's traffic.
pub const BG_TAG: u64 = 1 << 63;

/// Per-tenant collective-id spaces live in tag bits
/// `[TENANT_TAG_SHIFT, 63)`: drivers derive tenant `t`'s collective ids
/// from `1 + ((t as u64) << TENANT_TAG_SHIFT)`, which keeps tenant 0's
/// tags numerically identical to the single-job path (bitwise replay of
/// pre-tenant runs).
pub const TENANT_TAG_SHIFT: u32 = 40;

/// Recover the accounting slot owning a message tag: background traffic
/// maps to the extra slot `n_tenants`, everything else to the tag's
/// tenant bits (clamped, so foreign tags account to the last real tenant
/// instead of panicking). With `n_tenants == 0` (single-tenant mode)
/// everything is slot 0.
pub fn tenant_of_tag(tag: u64, n_tenants: usize) -> usize {
    if n_tenants == 0 {
        return 0;
    }
    if tag & BG_TAG != 0 {
        return n_tenants;
    }
    (((tag >> TENANT_TAG_SHIFT) & 0x7F_FFFF) as usize).min(n_tenants - 1)
}

/// One periodic background flow: `reps` messages of `bytes` from `src`
/// to `dst`, the first at `start_ns`, one every `period_ns` after.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BgFlow {
    pub src: Rank,
    pub dst: Rank,
    pub bytes: u64,
    pub start_ns: Ns,
    pub period_ns: Ns,
    pub reps: u32,
    /// Urgency class the flow contends under (1 = bulk neighbor).
    pub priority: Priority,
}

/// A seeded background-traffic schedule — the "noisy neighbor" model.
/// Like [`ChaosPlan`], the plan is pure data derived from its seed up
/// front: the same plan yields a byte-identical event stream, and
/// background traffic bends *timing* only — foreground payloads are
/// never touched (asserted in `tests/prop_tenant.rs`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BgPlan {
    pub seed: u64,
    pub flows: Vec<BgFlow>,
}

impl BgPlan {
    /// A plan with no flows (baseline in tests and benches).
    pub fn quiet(seed: u64) -> Self {
        Self { seed, flows: Vec::new() }
    }

    /// Derive a moderate background load from `seed` for a `p`-rank run
    /// of roughly `horizon_ns`: one to `p/2` periodic NIC-tier flows
    /// (never shm — the injector models fabric neighbors, not in-node
    /// copies), each 64 KiB–1 MiB every ~1/40 of the horizon, bulk
    /// class. Deterministic in its arguments, same contract as
    /// [`ChaosPlan::generate`].
    pub fn generate(seed: u64, topo: &Topology, p: usize, horizon_ns: Ns) -> Self {
        let mut r = Prng::seed(seed);
        let horizon = horizon_ns.max(1000);
        let mut flows = Vec::new();
        if p >= 2 {
            for _ in 0..1 + r.below((p as u64 / 2).max(1)) {
                let src = r.usize_below(p);
                // First peer ahead of src whose hop rides a NIC tier.
                let mut dst = (src + 1) % p;
                for k in 1..p {
                    let c = (src + k) % p;
                    if !topo.same_node(src, c) {
                        dst = c;
                        break;
                    }
                }
                if topo.same_node(src, dst) {
                    continue; // single-node fabric: no NIC tier to load
                }
                let bytes = (64 + r.below(961)) * 1024;
                let start_ns = r.below(horizon / 4 + 1);
                let period_ns = (horizon / 40).max(1) + r.below((horizon / 40).max(1));
                let reps =
                    (horizon.saturating_sub(start_ns) / period_ns + 1).min(10_000) as u32;
                flows.push(BgFlow { src, dst, bytes, start_ns, period_ns, reps, priority: 1 });
            }
        }
        Self { seed, flows }
    }

    /// Total bytes the plan will inject (all flows, all repetitions).
    pub fn total_bytes(&self) -> u64 {
        self.flows.iter().map(|f| f.bytes * f.reps as u64).sum()
    }
}

/// Persistent per-node compute slowdowns — the classic straggler model
/// (arxiv 1609.06870): unlike [`ChaosPlan::slowdown_milli`] these never
/// expire, and they compose multiplicatively with chaos slowdowns.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StragglerPlan {
    /// Per-node factor in milli-units (1000 = healthy, 2000 = 2×).
    pub factor_milli: Vec<u64>,
}

impl StragglerPlan {
    /// Every node healthy.
    pub fn healthy(p: usize) -> Self {
        Self { factor_milli: vec![1000; p] }
    }

    /// Parse `node:factor[,node:factor…]` (e.g. `3:2.0,7:1.5`);
    /// `all:factor` pins every node. Factors must lie in [0.1, 100].
    pub fn parse(spec: &str, p: usize) -> Result<Self, String> {
        let mut plan = Self::healthy(p);
        for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let (node_s, f_s) = part
                .split_once(':')
                .ok_or_else(|| format!("straggler `{part}`: expected node:factor"))?;
            let f: f64 = f_s
                .trim()
                .parse()
                .map_err(|_| format!("straggler `{part}`: bad factor `{}`", f_s.trim()))?;
            if !(0.1..=100.0).contains(&f) {
                return Err(format!("straggler `{part}`: factor must be in [0.1, 100]"));
            }
            let milli = (f * 1000.0).round() as u64;
            if node_s.trim() == "all" {
                plan.factor_milli = vec![milli; p];
            } else {
                let node: usize = node_s
                    .trim()
                    .parse()
                    .map_err(|_| format!("straggler `{part}`: bad node `{}`", node_s.trim()))?;
                if node >= p {
                    return Err(format!("straggler `{part}`: node {node} out of range (p={p})"));
                }
                plan.factor_milli[node] = milli;
            }
        }
        Ok(plan)
    }

    /// No node slowed?
    pub fn is_quiet(&self) -> bool {
        self.factor_milli.iter().all(|&m| m == 1000)
    }

    /// Largest per-node factor in milli-units (1000 when empty).
    pub fn max_milli(&self) -> u64 {
        self.factor_milli.iter().copied().max().unwrap_or(1000)
    }

    /// Mean per-node factor in milli-units (1000 when empty).
    pub fn mean_milli(&self) -> u64 {
        if self.factor_milli.is_empty() {
            return 1000;
        }
        self.factor_milli.iter().sum::<u64>() / self.factor_milli.len() as u64
    }
}

/// A logical message with egress pieces still on the wires (or, for an
/// injected cross-partition arrival, waiting on its Deliver event).
/// Entries are removed at delivery, so the map is bounded by the
/// in-flight count — not by every message ever sent.
struct InFlight {
    msg: MsgDesc,
    /// Egress pieces still on the wires. Delivery is scheduled when the
    /// count hits zero (the last rail finishes); 0 from the start for
    /// injected cross-partition arrivals.
    egress_left: u32,
}

/// Which shard of a node-partitioned fleet this simulator instance is.
#[derive(Debug, Clone, Copy)]
struct Part {
    shard: usize,
    shards: usize,
}

/// The simulator. Drive it by posting sends/computes, then repeatedly
/// calling [`NetSim::next`] and reacting to the returned events.
pub struct NetSim {
    topo: Topology,
    p: usize,
    queue: EventQueue<Internal>,
    /// Per-rank NIC egress RAILS: `nics[rank][rail]`, each an
    /// independent strict-priority server at the per-rail line rate.
    /// Single-rail topologies degenerate to the classic one-NIC model.
    nics: Vec<Vec<Nic>>,
    /// Per-RANK shared-memory egress channels (intra-node hops only):
    /// same serialization model as the per-rank NIC but a single free
    /// class — FIFO, no urgency, no preemption. Co-located ranks copy
    /// concurrently (each models its own copy engine / memory port).
    shms: Vec<Nic>,
    /// Messages currently on the wires / in flight, keyed by a
    /// monotonic per-simulator id.
    inflight: HashMap<u64, InFlight>,
    next_msg_id: u64,
    next_xfer_id: u64,
    /// Installed fault schedule ([`NetSim::set_chaos`]); None = healthy.
    chaos: Option<ChaosPlan>,
    /// Installed background-traffic schedule ([`NetSim::set_background`]).
    bg: Option<BgPlan>,
    /// Persistent straggler factors ([`NetSim::set_stragglers`]).
    stragglers: Option<StragglerPlan>,
    /// Tenant count for per-tenant accounting; 0 = single-tenant mode
    /// (the accounting vectors stay empty and untouched).
    n_tenants: usize,
    /// Active zero-bandwidth windows (they may overlap).
    zero_bw_active: u32,
    /// Partitioned mode: which shard this instance owns; None = the
    /// whole fabric (the classic serial simulator).
    part: Option<Part>,
    /// Cross-partition messages awaiting coordinator routing.
    outbox: Vec<Mail>,
    /// Trace recording buffer ([`NetSim::set_trace`]); None = disabled.
    /// Every hook is one `if let` on this option and no hook mutates
    /// state the event loop reads, so the disabled path is byte-
    /// identical to a build without tracing (see docs/TRACING.md).
    trace: Option<Box<TraceBuf>>,
    pub stats: SimStats,
    pub chaos_stats: ChaosStats,
}

/// The trace-track name of an egress channel.
fn track_of(chan: Chan) -> TrackChan {
    match chan {
        Chan::Inter { rail } => TrackChan::Rail(rail),
        Chan::Shm => TrackChan::Shm,
    }
}

/// Content identity of an externally-visible event (what trace spans
/// record as their [`Cause`]).
fn cause_of(ev: &SimEvent) -> Cause {
    match ev {
        SimEvent::MsgDelivered { msg, at } => Cause::Msg {
            at: *at,
            src: msg.src,
            dst: msg.dst,
            bytes: msg.bytes,
            priority: msg.priority,
            tag: msg.tag,
        },
        SimEvent::ComputeDone { node, tag, at } => {
            Cause::Compute { at: *at, node: *node, tag: *tag }
        }
    }
}

impl NetSim {
    pub fn new(topo: Topology, p: usize) -> Self {
        let rails = topo.max_rails().max(1) as usize;
        let nics = (0..p).map(|_| (0..rails).map(|_| Nic::default()).collect()).collect();
        let shms = (0..p).map(|_| Nic::default()).collect();
        Self {
            topo,
            p,
            queue: EventQueue::new(),
            nics,
            shms,
            inflight: HashMap::new(),
            next_msg_id: 0,
            next_xfer_id: 0,
            chaos: None,
            bg: None,
            stragglers: None,
            n_tenants: 0,
            zero_bw_active: 0,
            part: None,
            outbox: Vec::new(),
            trace: None,
            stats: SimStats::default(),
            chaos_stats: ChaosStats::default(),
        }
    }

    /// Enable or disable trace recording. Enabling mid-run records from
    /// now on (hops already in flight are skipped); disabling drops any
    /// unretrieved spans. Tracing never changes simulated behavior.
    pub fn set_trace(&mut self, on: bool) {
        match (on, self.trace.is_some()) {
            (true, false) => self.trace = Some(Box::default()),
            (false, true) => self.trace = None,
            _ => {}
        }
    }

    /// Is trace recording on?
    pub fn trace_enabled(&self) -> bool {
        self.trace.is_some()
    }

    /// Move the recorded spans out, leaving the buffer recording.
    /// `None` when tracing is disabled.
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.trace.as_deref_mut().map(TraceBuf::take)
    }

    /// Append a fully-formed record (executor/engine hooks). No-op when
    /// tracing is disabled.
    pub fn trace_push(&mut self, ev: TraceEvent) {
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.push(ev);
        }
    }

    /// Clone the spans recorded so far WITHOUT draining the buffer —
    /// mid-run probes (e.g. the contention-aware selection feedback loop
    /// sampling per-tier utilization) that must not disturb the final
    /// [`NetSim::take_trace`]. `None` when tracing is disabled.
    pub fn trace_snapshot(&self) -> Option<Trace> {
        self.trace.as_deref().map(|tr| Trace { events: tr.events.clone() })
    }

    /// Build shard `shard` of a `shards`-way node-partitioned fleet.
    /// The shard owns the contiguous node block [`shard_of`] maps to it;
    /// work posted for any other shard's ranks is silently ignored and
    /// messages destined off-shard surface as [`Mail`] via
    /// [`NetSim::take_mail`] instead of local deliveries. See
    /// [`crate::collectives::parexec`] for the coordinator that makes a
    /// fleet of shards behave exactly like one serial simulator.
    pub fn new_partition(topo: Topology, p: usize, shard: usize, shards: usize) -> Self {
        assert!(shard < shards, "shard {shard} of {shards}");
        let mut sim = Self::new(topo, p);
        sim.part = Some(Part { shard, shards });
        sim
    }

    /// Does this simulator instance own `rank`? Always true for the
    /// serial (non-partitioned) simulator.
    pub fn owns(&self, rank: Rank) -> bool {
        match self.part {
            Some(part) => shard_of(&self.topo, self.p, part.shards, rank) == part.shard,
            None => true,
        }
    }

    /// Install a fault schedule: flap windows and rail deaths become
    /// queued events relative to `now`, slowdown factors scale every
    /// subsequent [`NetSim::compute`]. The plan is pure data, so the
    /// run stays deterministic (same plan ⇒ same event stream).
    pub fn set_chaos(&mut self, plan: ChaosPlan) {
        let now = self.queue.now();
        for f in &plan.flaps {
            if f.zero_bw {
                self.queue.push_in(f.from.saturating_sub(now), Internal::ChaosGate { on: true });
                self.queue
                    .push_in(f.until.saturating_sub(now), Internal::ChaosGate { on: false });
            }
        }
        for (idx, d) in plan.rail_deaths.iter().enumerate() {
            assert!(d.node < self.p, "rail death on rank {} of {}", d.node, self.p);
            // Partitioned mode: a rail death is local to its node, so
            // only the owning shard schedules (and counts) it.
            if self.owns(d.node) {
                self.queue.push_in(d.at.saturating_sub(now), Internal::RailDie { idx });
            }
        }
        let mut plan = plan;
        plan.slowdown_milli.resize(self.p, 1000);
        self.chaos = Some(plan);
    }

    /// Install a background-traffic schedule: every flow's repetitions
    /// become queued injection events relative to `now`. Like chaos, the
    /// plan is pure data — same plan ⇒ same event stream. In partitioned
    /// mode each shard schedules only the flows whose source it owns.
    pub fn set_background(&mut self, plan: BgPlan) {
        let now = self.queue.now();
        for (i, f) in plan.flows.iter().enumerate() {
            assert!(f.src < self.p && f.dst < self.p, "background flow rank out of range");
            assert_ne!(f.src, f.dst, "background flow self-send");
            if f.reps > 0 && self.owns(f.src) {
                self.queue.push_in(
                    f.start_ns.saturating_sub(now),
                    Internal::BgInject { flow: i as u32, rep: 0 },
                );
            }
        }
        self.bg = Some(plan);
    }

    /// Install persistent straggler factors: every subsequent
    /// [`NetSim::compute`] on a slowed node stretches by its factor
    /// (composing multiplicatively with any chaos slowdown). Messages
    /// are never slowed — stragglers are a compute pathology.
    pub fn set_stragglers(&mut self, plan: StragglerPlan) {
        let mut plan = plan;
        plan.factor_milli.resize(self.p, 1000);
        self.stragglers = Some(plan);
    }

    /// Turn on per-tenant accounting for `n` tenants: sizes the
    /// [`SimStats`] tenant vectors to `n + 1` slots (the extra slot
    /// collects background traffic). Transfers are attributed by their
    /// tag's tenant bits ([`tenant_of_tag`]).
    pub fn set_tenants(&mut self, n: usize) {
        self.n_tenants = n;
        self.stats.tenant_bytes = vec![0; n + 1];
        self.stats.tenant_msgs = vec![0; n + 1];
        self.stats.tenant_busy_ns = vec![0; n + 1];
    }

    /// Tenant count accounting runs under (0 = single-tenant mode).
    pub fn num_tenants(&self) -> usize {
        self.n_tenants
    }

    /// Is `rail` of `node` dead (killed by the chaos plan)?
    pub fn rail_dead(&self, node: Rank, rail: usize) -> bool {
        self.nics[node][rail].dead
    }

    /// Surviving (non-dead) rails of `node`.
    pub fn alive_rails(&self, node: Rank) -> usize {
        self.nics[node].iter().filter(|n| !n.dead).count()
    }

    fn chan_mut(&mut self, node: Rank, chan: Chan) -> &mut Nic {
        match chan {
            Chan::Inter { rail } => &mut self.nics[node][rail as usize],
            Chan::Shm => &mut self.shms[node],
        }
    }

    pub fn now(&self) -> Ns {
        self.queue.now()
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn num_nodes(&self) -> usize {
        self.p
    }

    /// Post a point-to-point message. It contends for `msg.src`'s egress
    /// wires under strict priority; NIC-tier transfers are striped into
    /// [`Topology::stripe_count`] chunk pieces across the rails (pure
    /// per-chunk rail assignment `(i + src) % rails`), shared-memory
    /// copies ride the rank's single shm channel.
    pub fn send(&mut self, msg: MsgDesc) {
        assert!(msg.src < self.p && msg.dst < self.p, "rank out of range");
        assert_ne!(msg.src, msg.dst, "self-send");
        // Partitioned mode: only the shard owning the source simulates
        // (and accounts) the send — drivers replicated across shards may
        // post every rank's traffic and rely on this filter.
        if !self.owns(msg.src) {
            return;
        }
        let node = msg.src;
        let msg_id = self.next_msg_id;
        self.next_msg_id += 1;
        // Tier pricing: every hop costs its deepest-common-tier rate.
        // Hops confined to a shared-memory tier serialize on their own
        // channel, bypassing the NIC priority queue.
        let level = self.topo.level_of(msg.src, msg.dst);
        let shm = self.topo.same_node(msg.src, msg.dst);
        let overhead = self.topo.overhead_at(level);
        let gbps = self.topo.gbps_at(level);
        // Urgency classes apply only on the contended inter tier; the shm
        // channel is one free class (FIFO by transfer id). Striping runs
        // over the SURVIVING rails: with no rail deaths `alive` is the
        // identity [0..rails] and the assignment below is byte-identical
        // to the healthy `(i + src) % rails`.
        let (pieces, class, alive) = if shm {
            (1u32, 0, vec![0usize])
        } else {
            let level_rails =
                (self.topo.rails_at(level).max(1) as usize).min(self.nics[node].len());
            let mut alive: Vec<usize> =
                (0..level_rails).filter(|&r| !self.nics[node][r].dead).collect();
            if alive.is_empty() {
                // Every rail of this tier died; fall back to any
                // surviving physical rail (kill_rail guarantees one).
                alive = (0..self.nics[node].len()).filter(|&r| !self.nics[node][r].dead).collect();
            }
            assert!(!alive.is_empty(), "node {node} has no surviving rails");
            let pieces = self.topo.stripe_count(level, msg.bytes).min(alive.len() as u32);
            (pieces, msg.priority, alive)
        };
        self.stats.msgs_sent += 1;
        self.stats.bytes_sent += msg.bytes;
        self.stats.bytes_by_priority[msg.priority as usize] += msg.bytes;
        // Tenant attribution rides the tag (tenant id bits / BG bit);
        // outside multi-tenant mode the vectors are empty and the hot
        // path pays one predictable branch.
        let tenant = if self.n_tenants > 0 {
            let t = tenant_of_tag(msg.tag, self.n_tenants);
            self.stats.tenant_msgs[t] += 1;
            self.stats.tenant_bytes[t] += msg.bytes;
            t as u16
        } else {
            0
        };
        self.inflight.insert(msg_id, InFlight { msg: msg.clone(), egress_left: pieces });
        let now = self.queue.now();
        if let Some(tr) = self.trace.as_deref_mut() {
            // Pure service of the max-cost piece: the hop's egress time
            // with zero contention (the critical-path "service" term).
            let mut service: Ns = 0;
            for i in 0..pieces as u64 {
                let piece = msg.bytes * (i + 1) / pieces as u64 - msg.bytes * i / pieces as u64;
                service = service.max((overhead + super::wire_ns(piece, gbps)).max(1));
            }
            tr.start_hop(msg_id, level, pieces, service, now);
        }
        for i in 0..pieces as u64 {
            // Balanced split (same arithmetic as program::segments): the
            // pieces partition msg.bytes exactly.
            let piece = msg.bytes * (i + 1) / pieces as u64 - msg.bytes * i / pieces as u64;
            // Every piece pays its rail's injection overhead; pieces move
            // concurrently, so the overhead is not multiplied in wall
            // time — only in per-rail busy accounting.
            let cost = overhead + super::wire_ns(piece, gbps);
            let chan = if shm {
                Chan::Shm
            } else {
                Chan::Inter { rail: alive[(i as usize + msg.src) % alive.len()] as u32 }
            };
            let id = self.next_xfer_id;
            self.next_xfer_id += 1;
            let nic = self.chan_mut(node, chan);
            nic.slab.insert(
                id,
                Transfer {
                    msg_id,
                    remaining_ns: cost.max(1),
                    checkpoint: now,
                    running: false,
                    class,
                    tenant,
                },
            );
            nic.order.push(Reverse((class, id)));
            // Fast path: the channel is already busy with an equal-or-
            // higher priority transfer — no preemption, nothing to
            // reschedule.
            if let Some(run) = nic.running {
                if nic.head() == Some(run) {
                    continue;
                }
            }
            self.reschedule(node, chan);
        }
    }

    /// Post a compute timer on `node` for `dur_ns`; fires `ComputeDone{tag}`.
    /// A chaos slowdown factor for `node` stretches the duration.
    pub fn compute(&mut self, node: Rank, dur_ns: Ns, tag: u64) {
        assert!(node < self.p);
        if !self.owns(node) {
            return;
        }
        let mut dur = match &self.chaos {
            Some(plan) => {
                let m = plan.slowdown_milli.get(node).copied().unwrap_or(1000);
                if m != 1000 {
                    self.chaos_stats.slowdowns_applied += 1;
                }
                dur_ns.saturating_mul(m) / 1000
            }
            None => dur_ns,
        };
        // Persistent stragglers compose multiplicatively with chaos's
        // transient slowdowns (a straggler stays slow; chaos passes).
        if let Some(s) = &self.stragglers {
            let m = s.factor_milli.get(node).copied().unwrap_or(1000);
            if m != 1000 {
                dur = dur.saturating_mul(m) / 1000;
            }
        }
        let now = self.queue.now();
        if let Some(tr) = self.trace.as_deref_mut() {
            let cause = tr.current_cause;
            tr.push(TraceEvent::Compute(ComputeSpan {
                node,
                start: now,
                end: now + dur.max(1),
                tag,
                cause,
            }));
        }
        self.queue.push_in(dur.max(1), Internal::ComputeDone { node, tag });
    }

    /// Fire an event after `dur_ns` with no resource use (scheduling aid).
    pub fn timer(&mut self, node: Rank, dur_ns: Ns, tag: u64) {
        self.compute(node, dur_ns, tag);
    }

    /// Gate/ungate a node's egress (models absence of async progress:
    /// transfers only advance while the host is inside the library).
    /// Applies to EVERY channel — all NIC rails plus the shm channel;
    /// shared-memory copies also need host cycles, which a library
    /// without a progress thread only spends inside blocking calls.
    pub fn set_comm_gated(&mut self, node: Rank, gated: bool) {
        if !self.owns(node) {
            return;
        }
        let rails = self.nics[node].len();
        let chans = (0..rails)
            .map(|rail| Chan::Inter { rail: rail as u32 })
            .chain(std::iter::once(Chan::Shm));
        for chan in chans {
            if self.chan_mut(node, chan).gated != gated {
                self.chan_mut(node, chan).gated = gated;
                self.reschedule(node, chan);
            }
        }
    }

    /// True when no events remain (all transfers and timers drained).
    pub fn idle(&self) -> bool {
        self.queue.is_empty()
    }

    /// Egress rails each node drives (1 on single-rail topologies).
    pub fn num_rails(&self) -> usize {
        self.nics.first().map_or(1, |rails| rails.len())
    }

    /// Total ns `node`'s NIC wires were busy, summed over all rails.
    pub fn nic_busy_ns(&self, node: Rank) -> Ns {
        self.nics[node].iter().map(|n| n.busy_ns).sum()
    }

    /// Busy ns of one specific rail of `node`.
    pub fn rail_busy_ns(&self, node: Rank, rail: usize) -> Ns {
        self.nics[node][rail].busy_ns
    }

    /// NIC busy fraction so far for `node`: aggregate rail busy time over
    /// aggregate rail capacity (inter-tier wire utilization; the shm
    /// channel is tracked separately by [`Self::shm_utilization`]).
    /// Identical to the classic single-NIC fraction on 1-rail fabrics.
    pub fn nic_utilization(&self, node: Rank) -> f64 {
        if self.now() == 0 {
            return 0.0;
        }
        let rails = self.nics[node].len().max(1) as f64;
        self.nic_busy_ns(node) as f64 / (self.now() as f64 * rails)
    }

    /// Shared-memory channel busy fraction so far for `node`.
    pub fn shm_utilization(&self, node: Rank) -> f64 {
        if self.now() == 0 {
            return 0.0;
        }
        self.shms[node].busy_ns as f64 / self.now() as f64
    }

    /// Checkpoint progress of the currently-running transfer (if any) and
    /// re-elect the highest-priority transfer; (re)schedule its completion.
    fn reschedule(&mut self, node: Rank, chan: Chan) {
        let now = self.queue.now();
        let nic = match chan {
            Chan::Inter { rail } => &mut self.nics[node][rail as usize],
            Chan::Shm => &mut self.shms[node],
        };

        // 1. Stop the running transfer, banking its progress.
        let was_running = nic.running.take();
        if let Some(id) = was_running {
            if let Some(t) = nic.slab.get_mut(&id) {
                let elapsed = now - t.checkpoint;
                t.remaining_ns = t.remaining_ns.saturating_sub(elapsed);
                t.running = false;
            }
        }
        if let Some(since) = nic.busy_since.take() {
            nic.busy_ns += now - since;
            // The banked interval belongs to the transfer that held the
            // wire (still in the slab — EgressDone banks its own
            // interval before rescheduling).
            let (class, tenant) = was_running
                .and_then(|id| nic.slab.get(&id))
                .map_or((0, 0), |t| (t.class, t.tenant));
            if let Some(slot) = self.stats.tenant_busy_ns.get_mut(tenant as usize) {
                *slot += now - since;
            }
            if now > since {
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.push(TraceEvent::Busy(BusySpan {
                        node,
                        chan: track_of(chan),
                        class,
                        start: since,
                        end: now,
                    }));
                }
            }
        }
        nic.gen += 1;

        if nic.gated || nic.chaos_gated || nic.dead {
            return;
        }
        // 2. Elect the head: lowest (priority, id) — FIFO within a class.
        // The shm channel enqueues everything in one class, so its head
        // can only change when the running transfer finishes: preemption
        // is a NIC-only phenomenon (and only the NIC counts them).
        let Some(id) = nic.head() else { return };
        if let Some(prev) = was_running {
            if matches!(chan, Chan::Inter { .. }) && prev != id && nic.slab.contains_key(&prev)
            {
                self.stats.preemptions += 1;
            }
        }
        let head = nic.slab.get_mut(&id).expect("head is live");
        head.running = true;
        head.checkpoint = now;
        nic.running = Some(id);
        nic.busy_since = Some(now);
        let (remaining, gen, head_msg) = (head.remaining_ns, nic.gen, head.msg_id);
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.note_service(head_msg, now);
        }
        self.queue
            .push_in(remaining, Internal::EgressDone { node, chan, xfer: id, gen });
    }

    /// Advance to and return the next externally-visible event.
    pub fn next(&mut self) -> Option<SimEvent> {
        while let Some((at, ev)) = self.queue.pop() {
            if let Some(out) = self.dispatch(at, ev) {
                if let Some(tr) = self.trace.as_deref_mut() {
                    // Work the driver posts while reacting to `out` is
                    // attributed to it (the critical-path cause link).
                    tr.current_cause = Some(cause_of(&out));
                }
                return Some(out);
            }
        }
        None
    }

    /// Like [`NetSim::next`] but only processes events strictly before
    /// `horizon` — the partitioned window step. Events at or past the
    /// horizon stay queued; `None` means this window is exhausted, not
    /// that the simulation is done.
    pub fn next_before(&mut self, horizon: Ns) -> Option<SimEvent> {
        while self.queue.peek_time().is_some_and(|t| t < horizon) {
            let (at, ev) = self.queue.pop().expect("peeked event exists");
            if let Some(out) = self.dispatch(at, ev) {
                if let Some(tr) = self.trace.as_deref_mut() {
                    tr.current_cause = Some(cause_of(&out));
                }
                return Some(out);
            }
        }
        None
    }

    /// Timestamp of the earliest pending event, if any (the shard clock
    /// the partition coordinator takes the fleet minimum over).
    pub fn next_event_time(&self) -> Option<Ns> {
        self.queue.peek_time()
    }

    /// Inject a cross-partition arrival: `msg` delivers locally at
    /// absolute time `at` (already includes the in-flight latency the
    /// source shard priced). Conservative lookahead guarantees
    /// `at >= now` — mail never arrives in a shard's past.
    pub fn inject_delivery(&mut self, at: Ns, msg: MsgDesc) {
        debug_assert!(
            at >= self.queue.now(),
            "cross-partition mail at {at} violates shard clock {}",
            self.queue.now()
        );
        let msg_id = self.next_msg_id;
        self.next_msg_id += 1;
        self.inflight.insert(msg_id, InFlight { msg, egress_left: 0 });
        self.queue.push_at(at, Internal::Deliver { msg_id });
    }

    /// Drain the outbox of cross-partition messages produced since the
    /// last call (empty on the serial simulator).
    pub fn take_mail(&mut self) -> Vec<Mail> {
        std::mem::take(&mut self.outbox)
    }

    /// Fast-forward an idle simulator's clock to `at` so subsequently
    /// posted work starts there (no-op when the clock is already past
    /// it). Panics if a pending event would be skipped — batched
    /// drivers must process everything before `at` first.
    pub fn advance_idle_to(&mut self, at: Ns) {
        if let Some(t) = self.queue.peek_time() {
            assert!(t >= at, "advance_idle_to({at}) would skip a pending event at {t}");
        }
        self.queue.advance_to(at);
    }

    /// Process one internal event; `Some` = externally visible.
    fn dispatch(&mut self, at: Ns, ev: Internal) -> Option<SimEvent> {
        match ev {
            Internal::ComputeDone { node, tag } => {
                Some(SimEvent::ComputeDone { node, tag, at })
            }
            Internal::Deliver { msg_id } => {
                let inf = self.inflight.remove(&msg_id).expect("in-flight message exists");
                Some(SimEvent::MsgDelivered { msg: inf.msg, at })
            }
            Internal::EgressDone { node, chan, xfer, gen } => {
                let nic = match chan {
                    Chan::Inter { rail } => &mut self.nics[node][rail as usize],
                    Chan::Shm => &mut self.shms[node],
                };
                if nic.gen != gen {
                    return None; // stale: the channel was rescheduled since
                }
                let t = nic.slab.remove(&xfer).expect("generation-valid transfer exists");
                debug_assert!(t.running);
                nic.running = None;
                if let Some(since) = nic.busy_since.take() {
                    nic.busy_ns += at - since;
                    if let Some(slot) = self.stats.tenant_busy_ns.get_mut(t.tenant as usize) {
                        *slot += at - since;
                    }
                    if at > since {
                        if let Some(tr) = self.trace.as_deref_mut() {
                            tr.push(TraceEvent::Busy(BusySpan {
                                node,
                                chan: track_of(chan),
                                class: t.class,
                                start: since,
                                end: at,
                            }));
                        }
                    }
                }
                let msg_id = t.msg_id;
                // A striped transfer leaves the wire when its LAST rail
                // piece does; then in-flight latency (tier-priced, paid
                // once), then delivery.
                let done = {
                    let inf = self.inflight.get_mut(&msg_id).expect("in-flight message exists");
                    inf.egress_left -= 1;
                    inf.egress_left == 0
                };
                if done {
                    let (src, dst) = {
                        let m = &self.inflight[&msg_id].msg;
                        (m.src, m.dst)
                    };
                    let base = self.topo.latency_between(src, dst);
                    // A latency flap active on the hop's tier stretches
                    // the in-flight time — timing only, never the
                    // payload. Counted on the SOURCE shard in
                    // partitioned mode.
                    let mult = match &self.chaos {
                        Some(plan) => {
                            let level = self.topo.level_of(src, dst);
                            let m = plan.latency_mult_at(level, at);
                            if m != 1000 {
                                self.chaos_stats.latency_spikes += 1;
                            }
                            m
                        }
                        None => 1000,
                    };
                    let lat = if mult == 1000 { base } else { base.saturating_mul(mult) / 1000 };
                    if let Some(tr) = self.trace.as_deref_mut() {
                        // The hop record closes HERE, on the source
                        // shard, with the delivery time fully priced —
                        // the one site that covers both the local-
                        // delivery and cross-partition mail paths.
                        let m = &self.inflight[&msg_id].msg;
                        tr.finish_hop(msg_id, m, at, at.saturating_add(lat), mult);
                    }
                    if self.owns(dst) {
                        self.queue.push_in(lat, Internal::Deliver { msg_id });
                    } else {
                        // Destination lives on another shard: hand the
                        // message to the coordinator with its delivery
                        // time fully priced. `egress_at` preserves the
                        // serial delivery-queue insertion order on
                        // delivery-time ties.
                        let inf = self.inflight.remove(&msg_id).expect("just seen");
                        self.outbox.push(Mail {
                            at: at.saturating_add(lat),
                            egress_at: at,
                            msg: inf.msg,
                        });
                    }
                }
                self.reschedule(node, chan);
                None
            }
            Internal::ChaosGate { on } => {
                if on {
                    self.zero_bw_active += 1;
                    if self.zero_bw_active == 1 {
                        self.chaos_stats.zero_bw_windows += 1;
                        self.record_gate(at, true);
                        self.set_chaos_gate(true);
                    }
                } else {
                    self.zero_bw_active = self.zero_bw_active.saturating_sub(1);
                    if self.zero_bw_active == 0 {
                        self.record_gate(at, false);
                        self.set_chaos_gate(false);
                    }
                }
                None
            }
            Internal::RailDie { idx } => {
                let Some(plan) = &self.chaos else { return None };
                let RailDeath { node, rail, .. } = plan.rail_deaths[idx];
                self.kill_rail(node, rail as usize);
                None
            }
            Internal::BgInject { flow, rep } => {
                let Some(plan) = &self.bg else { return None };
                let f = plan.flows[flow as usize];
                if rep + 1 < f.reps {
                    self.queue
                        .push_in(f.period_ns.max(1), Internal::BgInject { flow, rep: rep + 1 });
                }
                self.send(MsgDesc {
                    src: f.src,
                    dst: f.dst,
                    bytes: f.bytes,
                    priority: f.priority,
                    tag: BG_TAG | flow as u64,
                });
                None
            }
        }
    }

    /// Record a fleet-wide gate transition. Every shard processes the
    /// same gate events, so only shard 0 records (the serial simulator
    /// always does) — merged traces carry each transition exactly once.
    fn record_gate(&mut self, at: Ns, on: bool) {
        let first_shard = match self.part {
            Some(p) => p.shard == 0,
            None => true,
        };
        if first_shard {
            if let Some(tr) = self.trace.as_deref_mut() {
                tr.push(TraceEvent::ChaosGate { at, on });
            }
        }
    }

    /// Open/close the zero-bandwidth gate on every NIC rail of every
    /// node (shared-memory channels keep flowing: a fabric brown-out
    /// does not stall in-node copies).
    fn set_chaos_gate(&mut self, on: bool) {
        for node in 0..self.p {
            for rail in 0..self.nics[node].len() {
                if self.nics[node][rail].chaos_gated != on {
                    self.nics[node][rail].chaos_gated = on;
                    self.reschedule(node, Chan::Inter { rail: rail as u32 });
                }
            }
        }
    }

    /// Kill one NIC rail: bank the running piece's progress, mark the
    /// rail dead, and migrate its queued pieces (in transfer-id order —
    /// deterministic, HashMap iteration never leaks into behavior) to
    /// the surviving rails via the same `(id + node) % alive` assignment
    /// striping uses. Refuses to kill a node's last surviving rail.
    fn kill_rail(&mut self, node: Rank, rail: usize) {
        let alive: Vec<usize> = (0..self.nics[node].len())
            .filter(|&r| r != rail && !self.nics[node][r].dead)
            .collect();
        if alive.is_empty() || self.nics[node][rail].dead {
            return; // last rail or already dead: refuse, keep the fabric live
        }
        self.nics[node][rail].dead = true;
        if let Some(tr) = self.trace.as_deref_mut() {
            // Only the owning shard schedules RailDie events (set_chaos
            // filters), so this records exactly once fleet-wide.
            tr.push(TraceEvent::RailDie {
                at: self.queue.now(),
                node,
                rail: rail as u32,
            });
        }
        // Banks the running piece's progress, accrues busy time, bumps
        // the generation (stale EgressDone events die), and — because
        // the rail is now dead — elects nothing.
        self.reschedule(node, Chan::Inter { rail: rail as u32 });
        let nic = &mut self.nics[node][rail];
        let mut orphans: Vec<(u64, Transfer)> = nic.slab.drain().collect();
        nic.order.clear();
        orphans.sort_by_key(|(id, _)| *id);
        self.chaos_stats.rails_killed += 1;
        self.chaos_stats.transfers_rerouted += orphans.len() as u64;
        let mut touched: Vec<usize> = Vec::new();
        let now = self.queue.now();
        for (id, mut t) in orphans {
            let target = alive[(id as usize + node) % alive.len()];
            t.running = false;
            t.checkpoint = now;
            let class = t.class;
            let dst = &mut self.nics[node][target];
            dst.slab.insert(id, t);
            dst.order.push(Reverse((class, id)));
            if !touched.contains(&target) {
                touched.push(target);
            }
        }
        for target in touched {
            // Skip the fast path: a migrated piece may outrank the
            // target rail's running head.
            self.reschedule(node, Chan::Inter { rail: target as u32 });
        }
    }

    /// Run the simulation to completion, collecting all events.
    pub fn drain(&mut self) -> Vec<SimEvent> {
        let mut out = Vec::new();
        while let Some(e) = self.next() {
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(src: Rank, dst: Rank, bytes: u64, prio: Priority, tag: u64) -> MsgDesc {
        MsgDesc { src, dst, bytes, priority: prio, tag }
    }

    fn sim() -> NetSim {
        // Round numbers: 8 Gbps = 1 byte/ns, alpha = 1000 ns, gamma = 100 ns.
        // Flat (empty tier stack): only the top tier exists.
        let topo = Topology::flat("test", 8.0, 1_000, 100, 1 << 20);
        NetSim::new(topo, 4)
    }

    #[test]
    fn single_message_timing() {
        let mut s = sim();
        s.send(msg(0, 1, 1_000, 1, 7));
        let ev = s.next().unwrap();
        // 100 overhead + 1000 wire + 1000 latency = 2100.
        assert_eq!(
            ev,
            SimEvent::MsgDelivered { msg: msg(0, 1, 1_000, 1, 7), at: 2_100 }
        );
        assert!(s.idle());
    }

    #[test]
    fn same_priority_is_fifo_serialized() {
        let mut s = sim();
        s.send(msg(0, 1, 1_000, 1, 1));
        s.send(msg(0, 2, 1_000, 1, 2));
        let e1 = s.next().unwrap();
        let e2 = s.next().unwrap();
        match (e1, e2) {
            (SimEvent::MsgDelivered { msg: m1, at: t1 },
             SimEvent::MsgDelivered { msg: m2, at: t2 }) => {
                assert_eq!(m1.tag, 1);
                assert_eq!(m2.tag, 2);
                assert_eq!(t1, 2_100);
                assert_eq!(t2, 3_200); // second waits 1100 egress, same latency
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn high_priority_preempts_bulk() {
        let mut s = sim();
        // Bulk: 100_000 bytes at prio 9 -> would finish egress at 100_100.
        s.send(msg(0, 1, 100_000, 9, 1));
        // Urgent message posted at t=0 (before any event pops): wins the
        // wire immediately since it has lower priority value.
        s.send(msg(0, 2, 1_000, 0, 2));
        let e1 = s.next().unwrap();
        match e1 {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2, "urgent must arrive first");
                // urgent: 100 + 1000 egress + 1000 latency
                assert_eq!(at, 2_100);
            }
            other => panic!("{other:?}"),
        }
        let e2 = s.next().unwrap();
        match e2 {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                // bulk egress = its own 100_100 pushed back by 1_100 of
                // urgent wire time -> 101_200, + 1000 latency.
                assert_eq!(at, 102_200);
            }
            other => panic!("{other:?}"),
        }
        assert!(s.stats.preemptions >= 1);
    }

    #[test]
    fn mid_flight_preemption_preserves_progress() {
        let mut s = sim();
        s.send(msg(0, 1, 100_000, 9, 1)); // egress done at 100_100
        // Let some compute marker pass at t=50_000, then post urgent.
        s.compute(3, 50_000, 42);
        let e = s.next().unwrap();
        assert_eq!(e, SimEvent::ComputeDone { node: 3, tag: 42, at: 50_000 });
        s.send(msg(0, 2, 1_000, 0, 2));
        // Urgent egress 100+1000 from t=50_000 -> 51_100, deliver 52_100.
        let e1 = s.next().unwrap();
        match e1 {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2);
                assert_eq!(at, 52_100);
            }
            other => panic!("{other:?}"),
        }
        // Bulk had 50_100 ns left at 50_000; resumes 51_100, egress done
        // 101_200, delivered 102_200. Progress was preserved (not restarted).
        let e2 = s.next().unwrap();
        match e2 {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                assert_eq!(at, 102_200);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn gating_freezes_egress() {
        let mut s = sim();
        s.set_comm_gated(0, true);
        s.send(msg(0, 1, 1_000, 1, 1));
        s.compute(0, 10_000, 9);
        // Only the compute fires while gated.
        assert_eq!(
            s.next().unwrap(),
            SimEvent::ComputeDone { node: 0, tag: 9, at: 10_000 }
        );
        s.set_comm_gated(0, false);
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 10_000 + 2_100),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn independent_nics_run_in_parallel() {
        let mut s = sim();
        s.send(msg(0, 1, 1_000, 1, 1));
        s.send(msg(2, 3, 1_000, 1, 2));
        let e1 = s.next().unwrap();
        let e2 = s.next().unwrap();
        // Both delivered at 2_100: separate egress wires.
        for e in [e1, e2] {
            match e {
                SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 2_100),
                other => panic!("{other:?}"),
            }
        }
    }

    /// 2 ranks/node: ranks {0,1} share a node, rank 2 is remote.
    /// Intra: 80 Gbps = 10 B/ns, alpha 200, gamma 10.
    fn smp() -> NetSim {
        let mut topo = Topology::flat("test-x2", 8.0, 1_000, 100, 1 << 20);
        topo.tiers = vec![crate::fabric::topology::TierSpec {
            ranks: 2,
            gbps: 80.0,
            latency_ns: 200,
            per_msg_overhead_ns: 10,
            shm: true,
            rails: 1,
        }];
        topo.validate().unwrap();
        NetSim::new(topo, 4)
    }

    #[test]
    fn two_tier_topology_prices_hops_by_tier() {
        let mut s = smp();
        s.send(msg(0, 1, 1_000, 1, 1)); // intra: 10 + 100 + 200 = 310
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                assert_eq!(at, 310);
            }
            other => panic!("{other:?}"),
        }
        s.send(msg(0, 2, 1_000, 1, 2)); // inter: 100 + 1000 + 1000 from t=310
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2);
                assert_eq!(at, 310 + 2_100);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn intra_hops_bypass_the_nic_priority_queue() {
        // A bulk intra-node copy 0→1 and an urgent inter-node message 0→2
        // posted back to back: they ride separate channels, so neither
        // waits for — or preempts — the other.
        let mut s = smp();
        s.send(msg(0, 1, 1_000_000, 9, 1)); // shm: 10 + 100_000 wire + 200
        s.send(msg(0, 2, 1_000, 0, 2)); // nic: 100 + 1_000 + 1_000
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2, "inter urgent must not queue behind the intra copy");
                assert_eq!(at, 2_100);
            }
            other => panic!("{other:?}"),
        }
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                assert_eq!(at, 100_210, "intra copy unaffected by NIC traffic");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.stats.preemptions, 0);
        // Channel utilization is tracked per tier.
        assert!(s.shm_utilization(0) > 0.0);
        assert!(s.nic_utilization(0) > 0.0);
    }

    #[test]
    fn shm_channel_ignores_urgency_classes() {
        // Two intra-node copies; the second carries an "urgent" class but
        // must NOT preempt: intra hops are demoted to a single free class
        // and serialize FIFO by issue order.
        let mut s = smp();
        s.send(msg(0, 1, 1_000_000, 9, 1)); // egress done 100_010
        s.send(msg(0, 1, 1_000, 0, 2));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1, "FIFO on shm despite the lower priority value");
                assert_eq!(at, 100_210);
            }
            other => panic!("{other:?}"),
        }
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2);
                // Queued behind: egress 100_010 + (10 + 100), then 200 in
                // flight.
                assert_eq!(at, 100_320);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.stats.preemptions, 0, "no preemption exists on the shm channel");
    }

    #[test]
    fn gating_freezes_both_channels() {
        let mut s = smp();
        s.set_comm_gated(0, true);
        s.send(msg(0, 1, 1_000, 1, 1)); // intra
        s.send(msg(0, 2, 1_000, 1, 2)); // inter
        s.compute(0, 10_000, 9);
        assert_eq!(
            s.next().unwrap(),
            SimEvent::ComputeDone { node: 0, tag: 9, at: 10_000 }
        );
        s.set_comm_gated(0, false);
        // Intra: 10 + 100 + 200 from t=10_000; inter: 100 + 1_000 + 1_000.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                assert_eq!(at, 10_310);
            }
            other => panic!("{other:?}"),
        }
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2);
                assert_eq!(at, 12_100);
            }
            other => panic!("{other:?}"),
        }
    }

    /// 3 levels: 2 ranks/node (shm), 4 ranks/rack (NIC at 16 Gbps = 2
    /// B/ns, alpha 500, gamma 50), cross-rack at 8 Gbps (alpha 1000).
    fn rack() -> NetSim {
        let mut topo = Topology::flat("test-x2r2", 8.0, 1_000, 100, 1 << 20);
        topo.tiers = vec![
            crate::fabric::topology::TierSpec {
                ranks: 2,
                gbps: 80.0,
                latency_ns: 200,
                per_msg_overhead_ns: 10,
                shm: true,
                rails: 1,
            },
            crate::fabric::topology::TierSpec {
                ranks: 4,
                gbps: 16.0,
                latency_ns: 500,
                per_msg_overhead_ns: 50,
                shm: false,
                rails: 1,
            },
        ];
        topo.validate().unwrap();
        NetSim::new(topo, 8)
    }

    #[test]
    fn three_level_hops_price_at_deepest_common_tier() {
        let mut s = rack();
        s.send(msg(0, 1, 1_000, 1, 1)); // node: 10 + 100 + 200 = 310
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (1, 310));
            }
            other => panic!("{other:?}"),
        }
        s.send(msg(0, 2, 1_000, 1, 2)); // rack: 50 + 500 + 500 from t=310
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (2, 310 + 1_050));
            }
            other => panic!("{other:?}"),
        }
        s.send(msg(0, 4, 1_000, 1, 3)); // cross-rack: 100 + 1_000 + 1_000
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (3, 1_360 + 2_100));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rack_tier_hops_ride_the_nic_priority_queue() {
        // An in-rack (non-shm tier) bulk transfer and an urgent cross-rack
        // message share rank 0's NIC: the urgent one must preempt — rack
        // hops are NIC traffic, only shm-tier hops bypass the queue.
        let mut s = rack();
        s.send(msg(0, 2, 100_000, 9, 1)); // rack: egress 50 + 50_000
        s.send(msg(0, 4, 1_000, 0, 2)); // cross-rack urgent
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2, "urgent cross-rack must preempt the rack bulk");
                assert_eq!(at, 100 + 1_000 + 1_000);
            }
            other => panic!("{other:?}"),
        }
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                // Rack egress 50_050 pushed back by the urgent 1_100,
                // then 500 in flight.
                assert_eq!(at, 50_050 + 1_100 + 500);
            }
            other => panic!("{other:?}"),
        }
        assert!(s.stats.preemptions >= 1);
    }

    #[test]
    fn utilization_accounting() {
        let mut s = sim();
        s.send(msg(0, 1, 10_000, 1, 1));
        s.drain();
        // Wire busy 10_100 of the 11_100 total (delivery at 11_100).
        assert!((s.nic_utilization(0) - 10_100.0 / 11_100.0).abs() < 1e-9);
    }

    /// Flat 2-rail fabric: 8 Gbps/rail = 1 B/ns, alpha 1000, gamma 100,
    /// chunk 1000 bytes.
    fn railed(rails: u32) -> NetSim {
        let topo = Topology::flat("test", 8.0, 1_000, 100, 1_000)
            .with_rails(rails)
            .unwrap();
        NetSim::new(topo, 4)
    }

    #[test]
    fn chunked_transfer_stripes_across_rails() {
        let mut s = railed(2);
        assert_eq!(s.num_rails(), 2);
        // 2000 bytes = 2 chunks: pieces of 1000 on rails 0 and 1, each
        // 100 + 1000 egress in parallel, delivery 1000 later.
        s.send(msg(0, 1, 2_000, 1, 7));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 7);
                assert_eq!(at, 2_100, "striped: wire halves, alpha+gamma do not");
            }
            other => panic!("{other:?}"),
        }
        // Each rail was busy gamma + its piece's wire time.
        assert_eq!(s.rail_busy_ns(0, 0), 1_100);
        assert_eq!(s.rail_busy_ns(0, 1), 1_100);
        assert_eq!(s.nic_busy_ns(0), 2_200);
        // Single message, single logical delivery, single stats entry.
        assert_eq!(s.stats.msgs_sent, 1);
        assert_eq!(s.stats.bytes_sent, 2_000);
        assert_eq!(s.stats.bytes_by_priority[1], 2_000);
        assert!(s.idle());
    }

    #[test]
    fn sub_chunk_messages_ride_one_rail() {
        // A latency-bound message (under one chunk) must behave exactly
        // as on the single-rail fabric: one rail, one overhead.
        let mut s1 = railed(1);
        let mut s2 = railed(2);
        for s in [&mut s1, &mut s2] {
            s.send(msg(0, 1, 999, 1, 1));
        }
        let at1 = match s1.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => at,
            other => panic!("{other:?}"),
        };
        let at2 = match s2.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => at,
            other => panic!("{other:?}"),
        };
        assert_eq!(at1, at2, "zero regression for latency-bound sizes");
        assert_eq!(at1, 100 + 999 + 1_000);
        // Exactly one rail accrued busy time.
        let busy: Vec<Ns> = (0..2).map(|r| s2.rail_busy_ns(0, r)).collect();
        assert_eq!(busy.iter().filter(|&&b| b > 0).count(), 1);
    }

    #[test]
    fn rails_preserve_priority_preemption() {
        let mut s = railed(2);
        // Bulk 20_000 bytes: 10_000-byte pieces on rails 0 and 1.
        s.send(msg(0, 1, 20_000, 9, 1));
        // Urgent sub-chunk message rides rail (0 + 0) % 2 = 0 and must
        // preempt ONLY that rail's piece.
        s.send(msg(0, 2, 500, 0, 2));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 2, "urgent first");
                assert_eq!(at, 100 + 500 + 1_000);
            }
            other => panic!("{other:?}"),
        }
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 1);
                // Rail 1's piece egresses undisturbed at 10_100; rail 0's
                // is pushed back by the urgent 600 to 10_700 — delivery
                // gates on the last piece.
                assert_eq!(at, 10_700 + 1_000);
            }
            other => panic!("{other:?}"),
        }
        assert!(s.stats.preemptions >= 1);
    }

    #[test]
    fn striping_is_work_conserving_modulo_per_rail_overhead() {
        // Same transfer on 1 vs 4 rails: summed busy time differs only by
        // the extra per-piece injection overheads (and ceil rounding).
        let bytes = 40_000u64;
        let mut s1 = railed(1);
        let mut s4 = railed(4);
        s1.send(msg(0, 1, bytes, 1, 1));
        s4.send(msg(0, 1, bytes, 1, 1));
        s1.drain();
        s4.drain();
        let wire1 = s1.nic_busy_ns(0) - 100; // one overhead
        let wire4 = s4.nic_busy_ns(0) - 4 * 100; // one per rail piece
        assert!(
            wire1.abs_diff(wire4) <= 4,
            "wire work must be conserved: {wire1} vs {wire4}"
        );
    }

    #[test]
    fn gating_freezes_every_rail() {
        let mut s = railed(2);
        s.set_comm_gated(0, true);
        s.send(msg(0, 1, 2_000, 1, 1)); // striped across both rails
        s.compute(0, 5_000, 9);
        assert_eq!(
            s.next().unwrap(),
            SimEvent::ComputeDone { node: 0, tag: 9, at: 5_000 }
        );
        s.set_comm_gated(0, false);
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 5_000 + 2_100),
            other => panic!("{other:?}"),
        }
    }

    // -- chaos mode ---------------------------------------------------------

    #[test]
    fn zero_bw_window_stalls_egress_exactly_for_the_window() {
        let mut s = sim();
        s.set_chaos(ChaosPlan {
            seed: 0,
            flaps: vec![FlapWindow {
                level: 0,
                from: 1_000,
                until: 5_000,
                zero_bw: true,
                latency_mult_milli: 1000,
            }],
            rail_deaths: vec![],
            slowdown_milli: vec![1000; 4],
        });
        // Egress would finish at 1_100; the window opens at 1_000 with
        // 100 ns of wire left, which resumes at 5_000: egress 5_100,
        // delivery 6_100.
        s.send(msg(0, 1, 1_000, 1, 7));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (7, 6_100));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.chaos_stats.zero_bw_windows, 1);
        assert!(s.idle());
    }

    #[test]
    fn latency_flap_stretches_in_flight_time_only() {
        let mut s = sim();
        s.set_chaos(ChaosPlan {
            seed: 0,
            flaps: vec![FlapWindow {
                level: 0,
                from: 0,
                until: 10_000,
                zero_bw: false,
                latency_mult_milli: 3_000,
            }],
            rail_deaths: vec![],
            slowdown_milli: vec![1000; 4],
        });
        s.send(msg(0, 1, 1_000, 1, 7));
        // Egress 100 + 1000 unchanged; latency 1000 × 3 = 3000.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (7, 1_100 + 3_000));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.chaos_stats.latency_spikes, 1);
    }

    #[test]
    fn slowdown_scales_compute_only() {
        let mut s = sim();
        let mut slow = vec![1000u64; 4];
        slow[2] = 2_500;
        s.set_chaos(ChaosPlan {
            seed: 0,
            flaps: vec![],
            rail_deaths: vec![],
            slowdown_milli: slow,
        });
        s.compute(2, 10_000, 1); // straggler: 25_000
        s.compute(3, 10_000, 2); // healthy: 10_000
        assert_eq!(
            s.next().unwrap(),
            SimEvent::ComputeDone { node: 3, tag: 2, at: 10_000 }
        );
        assert_eq!(
            s.next().unwrap(),
            SimEvent::ComputeDone { node: 2, tag: 1, at: 25_000 }
        );
        assert_eq!(s.chaos_stats.slowdowns_applied, 1);
        // Messages are not slowed.
        s.send(msg(2, 3, 1_000, 1, 9));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 25_000 + 2_100),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rail_death_migrates_queued_pieces_and_conserves_work() {
        let mut s = railed(2);
        s.set_chaos(ChaosPlan {
            seed: 0,
            flaps: vec![],
            rail_deaths: vec![RailDeath { node: 0, rail: 1, at: 5_000 }],
            slowdown_milli: vec![1000; 4],
        });
        // 20_000 bytes = two 10_000-byte pieces, one per rail, each
        // egress 100 + 10_000 = 10_100.
        s.send(msg(0, 1, 20_000, 1, 7));
        // At 5_000 rail 1 dies with 5_100 banked remaining; the piece
        // migrates behind rail 0's (FIFO by id): rail 0 finishes its own
        // at 10_100, runs the orphan 5_100 more -> egress 15_200,
        // delivery 16_200.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.tag, at), (7, 16_200));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(s.chaos_stats.rails_killed, 1);
        assert_eq!(s.chaos_stats.transfers_rerouted, 1);
        assert!(s.rail_dead(0, 1));
        assert_eq!(s.alive_rails(0), 1);
        // Work conservation: rail 1 was busy until its death, rail 0
        // carried the rest — the summed busy time is the full two-piece
        // cost.
        assert_eq!(s.rail_busy_ns(0, 1), 5_000);
        assert_eq!(s.rail_busy_ns(0, 0), 15_200);
        assert_eq!(s.nic_busy_ns(0), 2 * 10_100);
        // New sends stripe over the lone survivor.
        s.send(msg(0, 1, 20_000, 1, 8));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!(m.tag, 8);
                // One piece (lone survivor), full wire time: posted at
                // 16_200, egress 100 + 20_000 -> 36_300, delivery 37_300.
                assert_eq!(at, 37_300);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn last_rail_never_dies() {
        let mut s = railed(1);
        s.set_chaos(ChaosPlan {
            seed: 0,
            flaps: vec![],
            rail_deaths: vec![RailDeath { node: 0, rail: 0, at: 10 }],
            slowdown_milli: vec![1000; 4],
        });
        s.send(msg(0, 1, 1_000, 1, 1));
        // The kill is refused: traffic flows normally.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 2_100),
            other => panic!("{other:?}"),
        }
        assert_eq!(s.chaos_stats.rails_killed, 0);
        assert_eq!(s.alive_rails(0), 1);
    }

    #[test]
    fn chaos_plan_generation_is_deterministic_and_valid() {
        let topo = Topology::flat("t", 8.0, 1_000, 100, 512).with_rails(4).unwrap();
        let a = ChaosPlan::generate(42, &topo, 8, 1_000_000);
        let b = ChaosPlan::generate(42, &topo, 8, 1_000_000);
        assert_eq!(a, b, "same seed must derive the same plan");
        let c = ChaosPlan::generate(43, &topo, 8, 1_000_000);
        assert_ne!(a, c, "different seeds must differ");
        assert!(!a.flaps.is_empty());
        assert_eq!(a.slowdown_milli.len(), 8);
        for f in &a.flaps {
            assert!(f.from < f.until);
            assert!(topo.nic_levels().contains(&f.level));
        }
        for d in &a.rail_deaths {
            assert!(d.node < 8 && d.rail < 4);
        }
        // Never all rails of one node.
        for n in 0..8 {
            let kills = a.rail_deaths.iter().filter(|d| d.node == n).count();
            assert!(kills < 4);
        }
        // Shm tiers are never flapped.
        let smp = smp();
        let p = ChaosPlan::generate(7, smp.topology(), 4, 1_000_000);
        for f in &p.flaps {
            assert_eq!(f.level, smp.topology().top_level());
        }
    }

    // -- partitioned mode ----------------------------------------------------

    #[test]
    fn partitioned_shard_drops_foreign_work_and_mails_cross_shard_msgs() {
        let topo = Topology::flat("test", 8.0, 1_000, 100, 1 << 20);
        let mut s0 = NetSim::new_partition(topo.clone(), 4, 0, 2);
        let mut s1 = NetSim::new_partition(topo, 4, 1, 2);
        assert!(s0.owns(0) && s0.owns(1) && !s0.owns(2) && !s0.owns(3));
        assert!(s1.owns(2) && s1.owns(3) && !s1.owns(0) && !s1.owns(1));
        // Foreign send: silently ignored — no stats, no events.
        s1.send(msg(0, 1, 1_000, 1, 7));
        assert_eq!(s1.stats.msgs_sent, 0);
        assert!(s1.idle());
        // Local send on the owner: behaves exactly like the serial sim.
        s0.send(msg(0, 1, 1_000, 1, 7));
        assert_eq!(
            s0.next().unwrap(),
            SimEvent::MsgDelivered { msg: msg(0, 1, 1_000, 1, 7), at: 2_100 }
        );
        // Cross-shard send: egress simulated locally, delivery mailed.
        s0.send(msg(1, 2, 1_000, 2, 8));
        assert!(s0.next().is_none(), "no local delivery for a cross-shard message");
        let mail = s0.take_mail();
        assert_eq!(mail.len(), 1);
        assert_eq!(mail[0].msg, msg(1, 2, 1_000, 2, 8));
        // Posted at t=2_100 (clock after the first delivery): egress done
        // at 2_100 + 100 + 1_000 = 3_200, delivery one latency later.
        assert_eq!(mail[0].egress_at, 3_200);
        assert_eq!(mail[0].at, 4_200);
        // The destination shard injects and delivers at exactly that time.
        s1.inject_delivery(mail[0].at, mail[0].msg.clone());
        assert_eq!(
            s1.next().unwrap(),
            SimEvent::MsgDelivered { msg: msg(1, 2, 1_000, 2, 8), at: 4_200 }
        );
        assert!(s1.inflight.is_empty());
    }

    #[test]
    fn advance_idle_to_fast_forwards_the_clock() {
        let mut s = sim();
        s.advance_idle_to(10_000);
        s.send(msg(0, 1, 1_000, 1, 1));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 12_100),
            other => panic!("{other:?}"),
        }
        // Rewinding is a no-op, not an error, once the queue is idle.
        s.advance_idle_to(5);
        assert_eq!(s.now(), 12_100);
    }

    #[test]
    fn next_before_stops_at_the_horizon() {
        let mut s = sim();
        s.send(msg(0, 1, 1_000, 1, 1)); // egress done 1_100, delivery 2_100
        assert!(s.next_before(2_100).is_none(), "delivery at 2_100 is not before 2_100");
        assert_eq!(s.next_event_time(), Some(2_100));
        match s.next_before(2_101).unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 2_100),
            other => panic!("{other:?}"),
        }
        assert!(s.idle());
    }

    #[test]
    fn inflight_slab_is_bounded_by_live_messages() {
        let mut s = sim();
        for i in 0..10 {
            s.send(msg(0, 1, 1_000, 1, i));
        }
        s.drain();
        assert!(s.inflight.is_empty(), "delivered messages must leave the slab");
    }

    #[test]
    fn same_chaos_seed_yields_byte_identical_event_streams() {
        let topo = Topology::flat("t", 8.0, 1_000, 100, 512).with_rails(2).unwrap();
        let run = || {
            let mut s = NetSim::new(topo.clone(), 4);
            s.set_chaos(ChaosPlan::generate(99, &topo, 4, 200_000));
            for i in 0..12u64 {
                let src = (i % 4) as usize;
                let dst = (src + 1 + (i as usize % 3)) % 4;
                s.send(msg(src, dst, 700 * (i + 1), (i % 3) as u8, i));
            }
            (s.drain(), s.chaos_stats)
        };
        let (ev1, st1) = run();
        let (ev2, st2) = run();
        assert_eq!(ev1, ev2, "chaos must be deterministic under a seed");
        assert_eq!(st1, st2);
    }

    // -- trace layer ---------------------------------------------------------

    #[test]
    fn tracing_does_not_perturb_and_records_exact_hops() {
        let run = |traced: bool| {
            let mut s = sim();
            s.set_trace(traced);
            assert_eq!(s.trace_enabled(), traced);
            s.send(msg(0, 1, 100_000, 9, 1)); // bulk
            s.send(msg(0, 2, 1_000, 0, 2)); // urgent, preempts
            let events = s.drain();
            (events, s.take_trace())
        };
        let (ev_off, tr_off) = run(false);
        let (ev_on, tr_on) = run(true);
        assert_eq!(ev_off, ev_on, "tracing must not move a single event");
        assert!(tr_off.is_none());
        let tr = tr_on.unwrap().normalized();
        // Urgent hop: immediate service, egress 100 + 1_000, flight 1_000.
        let urgent = tr.hops().find(|h| h.tag == 2).unwrap();
        assert_eq!((urgent.posted_at, urgent.first_service_at), (0, 0));
        assert_eq!((urgent.egress_done_at, urgent.deliver_at), (1_100, 2_100));
        assert_eq!(urgent.service_ns, 1_100);
        assert_eq!(urgent.queue_ns() + urgent.stall_ns(), 0);
        // Bulk hop: pure service 100_100, stalled exactly the urgent's
        // wire time, delivered at the timing the plain tests pin.
        let bulk = tr.hops().find(|h| h.tag == 1).unwrap();
        assert_eq!(bulk.service_ns, 100_100);
        assert_eq!(bulk.stall_ns(), 1_100);
        assert_eq!(bulk.queue_ns(), 0);
        assert_eq!(bulk.deliver_at, 102_200);
        // Busy intervals tile the wire-holding time exactly.
        let busy: Ns = tr
            .events
            .iter()
            .filter_map(|e| match e {
                crate::trace::TraceEvent::Busy(b) => Some(b.end - b.start),
                _ => None,
            })
            .sum();
        assert_eq!(busy, 101_200);
    }

    #[test]
    fn compute_spans_carry_causes_and_slowdowns() {
        let mut s = sim();
        s.set_trace(true);
        s.send(msg(0, 1, 1_000, 1, 7));
        let first = s.next().unwrap(); // delivery at 2_100
        // Posted while reacting to the delivery: cause = that event.
        s.compute(1, 5_000, 42);
        s.drain();
        let tr = s.take_trace().unwrap();
        let comp = tr
            .events
            .iter()
            .find_map(|e| match e {
                crate::trace::TraceEvent::Compute(c) => Some(c.clone()),
                _ => None,
            })
            .unwrap();
        assert_eq!((comp.start, comp.end), (2_100, 7_100));
        match (comp.cause, first) {
            (Some(Cause::Msg { at, tag, .. }), SimEvent::MsgDelivered { msg: m, at: d }) => {
                assert_eq!((at, tag), (d, m.tag));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn partitioned_traces_merge_to_the_serial_trace() {
        let topo = Topology::flat("test", 8.0, 1_000, 100, 1 << 20);
        // Serial reference.
        let mut s = NetSim::new(topo.clone(), 4);
        s.set_trace(true);
        s.send(msg(0, 1, 1_000, 1, 7));
        s.next().unwrap();
        s.send(msg(1, 2, 1_000, 2, 8));
        s.drain();
        let serial = s.take_trace().unwrap().normalized();
        // Two shards driving the identical workload.
        let mut s0 = NetSim::new_partition(topo.clone(), 4, 0, 2);
        let mut s1 = NetSim::new_partition(topo, 4, 1, 2);
        s0.set_trace(true);
        s1.set_trace(true);
        s0.send(msg(0, 1, 1_000, 1, 7));
        s0.next().unwrap();
        s0.send(msg(1, 2, 1_000, 2, 8));
        assert!(s0.next().is_none());
        let mail = s0.take_mail();
        assert_eq!(mail.len(), 1);
        s1.inject_delivery(mail[0].at, mail[0].msg.clone());
        s1.next().unwrap();
        let merged = Trace::merge(vec![
            s0.take_trace().unwrap(),
            s1.take_trace().unwrap(),
        ]);
        assert_eq!(serial, merged, "per-shard buffers must merge to the serial trace");
        // The cross-shard hop was recorded once, on the source shard,
        // with the delivery time fully priced.
        let hop = merged.hops().find(|h| h.tag == 8).unwrap();
        assert_eq!(hop.deliver_at, 4_200);
    }

    // -- multi-tenant fabric -------------------------------------------------

    #[test]
    fn tenant_of_tag_routes_tag_spaces() {
        assert_eq!(tenant_of_tag(1, 0), 0, "single-tenant mode: everything slot 0");
        assert_eq!(tenant_of_tag(1, 2), 0);
        assert_eq!(tenant_of_tag(1 + (1u64 << TENANT_TAG_SHIFT), 2), 1);
        assert_eq!(tenant_of_tag(BG_TAG | 3, 2), 2, "background slot is last");
        assert_eq!(tenant_of_tag(7u64 << TENANT_TAG_SHIFT, 2), 1, "foreign tags clamp");
    }

    #[test]
    fn background_flows_inject_deterministically_and_carry_the_bg_tag() {
        let mut s = sim();
        s.set_background(BgPlan {
            seed: 0,
            flows: vec![BgFlow {
                src: 2,
                dst: 3,
                bytes: 1_000,
                start_ns: 500,
                period_ns: 10_000,
                reps: 2,
                priority: 1,
            }],
        });
        // First injection at 500: egress 100 + 1_000, delivery 1_000 later.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { msg: m, at } => {
                assert_eq!((m.src, m.dst), (2, 3));
                assert_ne!(m.tag & BG_TAG, 0, "background traffic is tagged");
                assert_eq!(at, 500 + 2_100);
            }
            other => panic!("{other:?}"),
        }
        // Second (and last) repetition at 10_500.
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 10_500 + 2_100),
            other => panic!("{other:?}"),
        }
        assert!(s.idle(), "reps bound the injector");
        assert_eq!(s.stats.msgs_sent, 2);
    }

    #[test]
    fn background_traffic_bends_foreground_timing_but_never_payloads() {
        let fg = msg(0, 1, 10_000, 1, 7);
        let run = |bg: Option<BgPlan>| {
            let mut s = sim();
            if let Some(plan) = bg {
                s.set_background(plan);
            }
            // Park until t=100 so the background flow holds the wire
            // before the foreground message is posted.
            s.compute(3, 100, 1);
            while let Some(ev) = s.next() {
                if matches!(ev, SimEvent::ComputeDone { .. }) {
                    break;
                }
            }
            s.send(fg.clone());
            let mut fg_at = None;
            while let Some(ev) = s.next() {
                if let SimEvent::MsgDelivered { msg: m, at } = ev {
                    if m.tag & BG_TAG == 0 {
                        assert_eq!(m, fg, "payloads are never bent by background traffic");
                        fg_at = Some(at);
                    }
                }
            }
            fg_at.expect("foreground message delivered")
        };
        let quiet_at = run(None);
        assert_eq!(quiet_at, 100 + 10_100 + 1_000);
        // A same-class neighbor on rank 0's NIC from t=0 delays it.
        let noisy_at = run(Some(BgPlan {
            seed: 1,
            flows: vec![BgFlow {
                src: 0,
                dst: 2,
                bytes: 50_000,
                start_ns: 0,
                period_ns: 1,
                reps: 1,
                priority: 1,
            }],
        }));
        assert_eq!(noisy_at, 50_100 + 10_100 + 1_000, "queued behind the neighbor");
    }

    #[test]
    fn per_tenant_accounting_splits_bytes_and_busy_time() {
        let mut s = sim();
        s.set_tenants(2);
        s.send(msg(0, 1, 1_000, 1, 1)); // tenant 0's tag space
        s.send(msg(2, 3, 2_000, 1, 1 + (1u64 << TENANT_TAG_SHIFT))); // tenant 1
        s.set_background(BgPlan {
            seed: 0,
            flows: vec![BgFlow {
                src: 1,
                dst: 2,
                bytes: 4_000,
                start_ns: 0,
                period_ns: 1,
                reps: 1,
                priority: 1,
            }],
        });
        s.drain();
        assert_eq!(s.num_tenants(), 2);
        assert_eq!(s.stats.tenant_bytes, vec![1_000, 2_000, 4_000]);
        assert_eq!(s.stats.tenant_msgs, vec![1, 1, 1]);
        // Wire-busy lands on the owning tenant: overhead + bytes at 1 B/ns,
        // each sender on its own uncontended NIC.
        assert_eq!(s.stats.tenant_busy_ns, vec![1_100, 2_100, 4_100]);
        // The aggregate stats are unchanged by the split.
        assert_eq!(s.stats.bytes_sent, 7_000);
        assert_eq!(s.stats.msgs_sent, 3);
    }

    #[test]
    fn stragglers_persist_and_compose_with_chaos() {
        let mut s = sim();
        s.set_stragglers(StragglerPlan::parse("1:2.0", 4).unwrap());
        s.compute(0, 10_000, 1);
        s.compute(1, 10_000, 2);
        assert_eq!(s.next().unwrap(), SimEvent::ComputeDone { node: 0, tag: 1, at: 10_000 });
        assert_eq!(s.next().unwrap(), SimEvent::ComputeDone { node: 1, tag: 2, at: 20_000 });
        // Still slow later (persistent, unlike chaos windows), and a
        // chaos slowdown composes multiplicatively: 1.5 × 2.0 = 3×.
        let mut slow = vec![1000u64; 4];
        slow[1] = 1_500;
        s.set_chaos(ChaosPlan { seed: 0, flaps: vec![], rail_deaths: vec![], slowdown_milli: slow });
        s.compute(1, 10_000, 3);
        assert_eq!(s.next().unwrap(), SimEvent::ComputeDone { node: 1, tag: 3, at: 50_000 });
        // Messages are never slowed by stragglers.
        s.send(msg(1, 2, 1_000, 1, 9));
        match s.next().unwrap() {
            SimEvent::MsgDelivered { at, .. } => assert_eq!(at, 50_000 + 2_100),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn straggler_plans_parse_and_validate() {
        let p = StragglerPlan::parse("3:2.0, 1:1.5", 4).unwrap();
        assert_eq!(p.factor_milli, vec![1000, 1500, 1000, 2000]);
        assert_eq!(p.max_milli(), 2000);
        assert_eq!(p.mean_milli(), 1375);
        assert!(!p.is_quiet());
        let all = StragglerPlan::parse("all:1.2", 3).unwrap();
        assert_eq!(all.factor_milli, vec![1200; 3]);
        assert!(StragglerPlan::parse("9:2.0", 4).is_err(), "node out of range");
        assert!(StragglerPlan::parse("1", 4).is_err(), "missing factor");
        assert!(StragglerPlan::parse("1:zero", 4).is_err(), "bad factor");
        assert!(StragglerPlan::parse("1:0.0", 4).is_err(), "factor below range");
        assert!(StragglerPlan::healthy(2).is_quiet());
    }

    #[test]
    fn background_plan_generation_is_deterministic_and_valid() {
        let topo = Topology::flat("t", 8.0, 1_000, 100, 1 << 20);
        let a = BgPlan::generate(5, &topo, 8, 1_000_000);
        let b = BgPlan::generate(5, &topo, 8, 1_000_000);
        assert_eq!(a, b, "same seed must derive the same plan");
        assert!(!a.flows.is_empty());
        assert!(a.total_bytes() > 0);
        for f in &a.flows {
            assert!(f.src < 8 && f.dst < 8 && f.src != f.dst);
            assert!(!topo.same_node(f.src, f.dst), "background flows ride NIC tiers");
            assert!(f.reps >= 1 && f.period_ns >= 1);
        }
        assert_ne!(a, BgPlan::generate(6, &topo, 8, 1_000_000));
        assert!(BgPlan::quiet(5).flows.is_empty());
        // Shm peers are skipped in favor of NIC-tier partners.
        let s = smp();
        let g = BgPlan::generate(7, s.topology(), 4, 1_000_000);
        for f in &g.flows {
            assert!(!s.topology().same_node(f.src, f.dst));
        }
    }

    #[test]
    fn single_tenant_paths_are_untouched_by_tenant_machinery() {
        // Default-constructed sim: tenant vectors stay empty, timings as
        // every other test in this file pins them.
        let mut s = sim();
        s.send(msg(0, 1, 1_000, 1, 7));
        s.drain();
        assert!(s.stats.tenant_bytes.is_empty());
        assert!(s.stats.tenant_msgs.is_empty());
        assert!(s.stats.tenant_busy_ns.is_empty());
        assert_eq!(s.num_tenants(), 0);
    }
}

"""Fused scaled-dot-product attention Pallas kernel.

One grid cell per (batch*head): the whole (S, D) slice is staged into VMEM,
QK^T, causal mask, softmax and PV happen in one fused kernel — no (S, S)
probability matrix ever round-trips to HBM. That is the same insight as
flash-attention expressed in the TPU/Pallas model: BlockSpec does the
HBM->VMEM staging that warp-level tiling does on GPUs.

interpret=True on this image (see matmul.py header).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, scale: float):
    q = q_ref[0].astype(jnp.float32)  # (S, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = q.shape[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        logits = jnp.where(rows >= cols, logits, -1e30)
    # Numerically-stable softmax, fused in VMEM.
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, causal: bool = True):
    """softmax(q k^T / sqrt(D) [+causal]) v, fused per (batch, head).

    q, k, v: (B, H, S, D). Returns (B, H, S, D) in q.dtype.
    """
    b, h, s, d = q.shape
    scale = 1.0 / (d ** 0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    spec = pl.BlockSpec((1, s, d), lambda i: (i, 0, 0))
    out = pl.pallas_call(
        functools.partial(_attn_kernel, causal=causal, scale=scale),
        grid=(b * h,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


def vmem_bytes(s: int, d: int, dtype_bytes: int = 4) -> int:
    """Per-grid-cell VMEM: q,k,v,o slices + the (S,S) logits scratch."""
    return 4 * s * d * dtype_bytes + s * s * 4

"""mlsl-rs compile path (build-time only; never imported at runtime).

L2 model (model.py) + L1 Pallas kernels (kernels/) are AOT-lowered by
aot.py into artifacts/*.hlo.txt, which the Rust runtime loads via PJRT.
"""

//! Symbolic executor for collective programs — the correctness oracle.
//!
//! Instead of floats, every buffer element carries a *contribution vector*:
//! `coeff[k]` = how many times rank k's initial value for that element has
//! been summed in. Executing a program set symbolically and checking the
//! final coefficients proves algebraic correctness for ANY input data
//! (sum-reduction is linear), which is what the proptest suite asserts for
//! every algorithm × (p, n).
//!
//! Execution model matches the real executor: each rank runs its program
//! strictly in step order; a step's send reads the buffer *now*; messages
//! between a (src, dst) pair are FIFO. Scheduling is a fair round-robin
//! over ranks, so a deadlock (circular wait) is detected as "no progress".
//!
//! # Wire precision
//!
//! Compressed collectives ([`super::quant::WireDtype`]) reuse these exact
//! programs: the wire dtype changes only how a payload is encoded on the
//! fabric (bytes per element), never which ranges move between which ranks
//! in which order. `build` takes no dtype, so a symbolic proof here covers
//! every wire precision *structurally* — each element of the reduced result
//! still receives exactly one contribution from every rank. The numeric
//! side (bounded quantization error, error-feedback convergence) is pinned
//! separately by `quant::max_roundtrip_error` and `tests/prop_quant.rs`.

use std::collections::{HashMap, VecDeque};

use super::program::{CollectiveKind, Program};
use crate::Rank;

/// Contribution matrix for one rank's buffer: `buf[e][k]` = multiplicity of
/// rank k's initial element e.
pub type SymBuf = Vec<Vec<u32>>;

/// Initial symbolic buffers for a collective kind.
pub fn init_bufs(kind: CollectiveKind, p: usize, n: usize) -> Vec<SymBuf> {
    let mut bufs = vec![vec![vec![0u32; p]; n]; p];
    match kind {
        CollectiveKind::Allreduce
        | CollectiveKind::ReduceScatter
        | CollectiveKind::Reduce { .. }
        | CollectiveKind::Barrier => {
            for (r, buf) in bufs.iter_mut().enumerate() {
                for e in buf.iter_mut() {
                    e[r] = 1;
                }
            }
        }
        CollectiveKind::Broadcast { root } => {
            for e in bufs[root].iter_mut() {
                e[root] = 1;
            }
        }
        CollectiveKind::Allgather => {
            // Rank r owns segment r; its identity is (rank r, its own data).
            let seg = super::program::segments(n, p);
            for (r, buf) in bufs.iter_mut().enumerate() {
                for e in &mut buf[seg[r]..seg[r + 1]] {
                    e[r] = 1;
                }
            }
        }
    }
    bufs
}

/// Execute the programs symbolically. Returns final buffers, or an error
/// describing the deadlock/step mismatch.
pub fn run(programs: &[Program], mut bufs: Vec<SymBuf>) -> Result<Vec<SymBuf>, String> {
    let p = programs.len();
    let mut pc = vec![0usize; p]; // per-rank program counter
    let mut sent = vec![false; p]; // current step's send already issued?
    let mut wires: HashMap<(Rank, Rank), VecDeque<Vec<Vec<u32>>>> = HashMap::new();

    loop {
        let mut progressed = false;
        let mut all_done = true;
        for r in 0..p {
            let prog = &programs[r];
            if pc[r] >= prog.steps.len() {
                continue;
            }
            all_done = false;
            let step = &prog.steps[pc[r]];
            // The send half of a step never blocks (unbounded fabric) and
            // is issued as soon as the step is reached; the recv half
            // completes the step. Send and recv ranges never overlap in
            // our algorithms, so the send reads pre-recv state — matching
            // the real executor.
            if let (Some(sd), false) = (&step.send, sent[r]) {
                let payload: Vec<Vec<u32>> =
                    bufs[r][sd.range.off..sd.range.end()].to_vec();
                wires.entry((r, sd.to)).or_default().push_back(payload);
                sent[r] = true;
                progressed = true;
            }
            let recv_ready = match &step.recv {
                None => true,
                Some(rv) => wires
                    .get(&(rv.from, r))
                    .map_or(false, |q| !q.is_empty()),
            };
            if !recv_ready {
                continue;
            }
            if let Some(rv) = &step.recv {
                let payload = wires
                    .get_mut(&(rv.from, r))
                    .and_then(|q| q.pop_front())
                    .expect("checked above");
                if payload.len() != rv.range.len {
                    return Err(format!(
                        "rank {r} step {}: recv size {} != range {}",
                        pc[r],
                        payload.len(),
                        rv.range.len
                    ));
                }
                for (i, contrib) in payload.into_iter().enumerate() {
                    let e = &mut bufs[r][rv.range.off + i];
                    if rv.reduce {
                        for (a, b) in e.iter_mut().zip(contrib) {
                            *a += b;
                        }
                    } else {
                        *e = contrib;
                    }
                }
            }
            pc[r] += 1;
            sent[r] = false;
            progressed = true;
        }
        if all_done {
            // No messages may be left on the wires.
            let leftover: usize = wires.values().map(|q| q.len()).sum();
            if leftover > 0 {
                return Err(format!("{leftover} unconsumed messages"));
            }
            return Ok(bufs);
        }
        if !progressed {
            return Err(format!("deadlock: pcs={pc:?}"));
        }
    }
}

/// Check final buffers against the semantics of `kind`.
pub fn check(kind: CollectiveKind, p: usize, n: usize, bufs: &[SymBuf]) -> Result<(), String> {
    let ones = vec![1u32; p];
    let seg = super::program::segments(n, p);
    match kind {
        CollectiveKind::Allreduce => {
            for (r, buf) in bufs.iter().enumerate() {
                for (e, c) in buf.iter().enumerate() {
                    if *c != ones {
                        return Err(format!("rank {r} elem {e}: {c:?}"));
                    }
                }
            }
        }
        CollectiveKind::ReduceScatter => {
            // Rank r must own segment (r+1)%p fully reduced (ring layout;
            // hierarchical reduce-scatter uses NATURAL layout — see
            // [`check_reduce_scatter_layout`]).
            check_reduce_scatter_layout(p, n, bufs, 1)?;
        }
        CollectiveKind::Allgather => {
            for (r, buf) in bufs.iter().enumerate() {
                for i in 0..p {
                    for e in seg[i]..seg[i + 1] {
                        let mut want = vec![0u32; p];
                        want[i] = 1;
                        if buf[e] != want {
                            return Err(format!("rank {r} elem {e}: {:?}", buf[e]));
                        }
                    }
                }
            }
        }
        CollectiveKind::Broadcast { root } => {
            let mut want = vec![0u32; p];
            want[root] = 1;
            for (r, buf) in bufs.iter().enumerate() {
                for (e, c) in buf.iter().enumerate() {
                    if *c != want {
                        return Err(format!("rank {r} elem {e}: {c:?}"));
                    }
                }
            }
        }
        CollectiveKind::Reduce { root } => {
            for (e, c) in bufs[root].iter().enumerate() {
                if *c != ones {
                    return Err(format!("root elem {e}: {c:?}"));
                }
            }
        }
        CollectiveKind::Barrier => {} // completion is the postcondition
    }
    Ok(())
}

/// Reduce-scatter postcondition under an explicit ownership layout: rank
/// r must own segment (r + owner_shift) mod p fully reduced. The flat
/// ring pipeline produces shift 1; the hierarchical builders produce
/// NATURAL ownership (shift 0).
pub fn check_reduce_scatter_layout(
    p: usize,
    n: usize,
    bufs: &[SymBuf],
    owner_shift: usize,
) -> Result<(), String> {
    let ones = vec![1u32; p];
    let seg = super::program::segments(n, p);
    for (r, buf) in bufs.iter().enumerate() {
        let own = (r + owner_shift) % p;
        for e in seg[own]..seg[own + 1] {
            if buf[e] != ones {
                return Err(format!("rank {r} elem {e}: {:?}", buf[e]));
            }
        }
    }
    Ok(())
}

/// One-call helper: build → run → check. Layout-aware: hierarchical
/// reduce-scatter is checked against its natural ownership.
pub fn verify(kind: CollectiveKind, alg: super::Algorithm, p: usize, n: usize) -> Result<(), String> {
    let programs = super::program::build(kind, alg, p, n).map_err(|e| e.to_string())?;
    let bufs = init_bufs(kind, p, n);
    let finals = run(&programs, bufs)?;
    if kind == CollectiveKind::ReduceScatter
        && matches!(alg, super::Algorithm::Hierarchical { .. })
    {
        return check_reduce_scatter_layout(p, n, &finals, 0);
    }
    check(kind, p, n, &finals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::Algorithm as A;
    use CollectiveKind as K;

    #[test]
    fn ring_allreduce_correct() {
        for p in 1..=9 {
            for n in [1usize, 2, 7, 16, 33] {
                verify(K::Allreduce, A::Ring, p, n)
                    .unwrap_or_else(|e| panic!("p={p} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn rdoubling_allreduce_correct() {
        for p in [1usize, 2, 4, 8, 16] {
            for n in [1usize, 5, 64] {
                verify(K::Allreduce, A::RecursiveDoubling, p, n)
                    .unwrap_or_else(|e| panic!("p={p} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn halving_doubling_allreduce_correct() {
        for p in [2usize, 4, 8, 16, 32] {
            for n in [32usize, 33, 64, 100, 1024] {
                verify(K::Allreduce, A::HalvingDoubling, p, n)
                    .unwrap_or_else(|e| panic!("p={p} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn hierarchical_allreduce_correct() {
        // Mixed node counts and shapes, including non-power-of-two leader
        // counts (inner falls back to ring) and p == ranks_per_node.
        for (p, rpn) in
            [(4, 2), (8, 2), (8, 4), (8, 8), (12, 3), (12, 4), (16, 4), (6, 3), (9, 3), (15, 5)]
        {
            for n in [1usize, 7, 33, 100] {
                verify(K::Allreduce, A::hier(&[rpn]), p, n)
                    .unwrap_or_else(|e| panic!("p={p} rpn={rpn} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn multi_level_hierarchical_collectives_correct() {
        // 3- and 4-level stacks (socket → node → rack shapes), driven
        // through every hierarchical builder.
        for (p, groups) in [
            (8usize, vec![2usize, 4]),
            (16, vec![2, 8]),
            (24, vec![2, 4]),
            (24, vec![2, 12]),
            (36, vec![3, 18]),
            (16, vec![2, 4, 8]),
            (48, vec![2, 8, 24]),
        ] {
            let alg = A::hier(&groups);
            for n in [1usize, 37, 100] {
                verify(K::Allreduce, alg, p, n)
                    .unwrap_or_else(|e| panic!("allreduce p={p} {groups:?} n={n}: {e}"));
                verify(K::ReduceScatter, alg, p, n)
                    .unwrap_or_else(|e| panic!("rs p={p} {groups:?} n={n}: {e}"));
                verify(K::Allgather, alg, p, n)
                    .unwrap_or_else(|e| panic!("ag p={p} {groups:?} n={n}: {e}"));
            }
            for root in [0usize, 1, p / 2, p - 1] {
                verify(K::Broadcast { root }, alg, p, 13)
                    .unwrap_or_else(|e| panic!("bcast p={p} {groups:?} root={root}: {e}"));
            }
        }
    }

    #[test]
    fn natural_reduce_scatter_correct() {
        use crate::collectives::program::reduce_scatter_natural;
        for p in 1..=8 {
            let n = 24;
            let progs = reduce_scatter_natural(p, n);
            let finals = run(&progs, init_bufs(K::ReduceScatter, p, n)).unwrap();
            check_reduce_scatter_layout(p, n, &finals, 0)
                .unwrap_or_else(|e| panic!("p={p}: {e}"));
        }
    }

    #[test]
    fn hierarchical_all_inner_algorithms_correct() {
        use crate::collectives::program::allreduce_hierarchical;
        // Power-of-two leader counts admit every inner algorithm.
        for inner in [A::Ring, A::RecursiveDoubling, A::HalvingDoubling] {
            for (p, rpn) in [(8, 2), (16, 4), (16, 2)] {
                let progs = allreduce_hierarchical(p, 40, rpn, inner);
                let finals = run(&progs, init_bufs(K::Allreduce, p, 40))
                    .unwrap_or_else(|e| panic!("{inner:?} p={p} rpn={rpn}: {e}"));
                check(K::Allreduce, p, 40, &finals)
                    .unwrap_or_else(|e| panic!("{inner:?} p={p} rpn={rpn}: {e}"));
            }
        }
    }

    #[test]
    fn reduce_scatter_correct() {
        for p in 1..=8 {
            verify(K::ReduceScatter, A::Ring, p, 24).unwrap();
        }
    }

    #[test]
    fn allgather_correct() {
        for p in 1..=8 {
            verify(K::Allgather, A::Ring, p, 24).unwrap();
        }
    }

    #[test]
    fn allgather_rdoubling_correct() {
        for p in [1usize, 2, 4, 8, 16, 32] {
            for n in [1usize, 5, 24, 33, 100] {
                verify(K::Allgather, A::RecursiveDoubling, p, n)
                    .unwrap_or_else(|e| panic!("p={p} n={n}: {e}"));
            }
        }
    }

    #[test]
    fn broadcast_correct_all_roots() {
        for p in 1..=9 {
            for root in 0..p {
                verify(K::Broadcast { root }, A::Ring, p, 11).unwrap();
            }
        }
    }

    #[test]
    fn reduce_correct_all_roots() {
        for p in 1..=9 {
            for root in 0..p {
                verify(K::Reduce { root }, A::Ring, p, 11).unwrap();
            }
        }
    }

    #[test]
    fn every_wire_precision_pick_reuses_a_verified_program() {
        // The wire-aware selector may pair any precision with any
        // algorithm; whatever it picks must be a program set this
        // executor proves correct, because compression never rewrites
        // the step structure. Sweep the menu across shapes and sizes on
        // a slow fabric (where compressed candidates actually win).
        use crate::collectives::quant::WireDtype;
        use crate::collectives::selector::choose_algorithm_wire;
        use crate::topo::presets;
        let topo = presets::eth_10g_smp(8);
        for p in [2usize, 3, 4, 8, 12, 16] {
            for bytes in [256u64, 64 << 10, 4 << 20] {
                for menu in [
                    &WireDtype::ALL[..],
                    &[WireDtype::Int8Block][..],
                    &[WireDtype::Bf16][..],
                ] {
                    let (alg, wire) = choose_algorithm_wire(&topo, p, bytes, menu, 1000);
                    let n = (bytes as usize).div_ceil(4).min(200);
                    verify(K::Allreduce, alg, p, n).unwrap_or_else(|e| {
                        panic!("p={p} bytes={bytes} pick={alg:?}@{wire}: {e}")
                    });
                }
            }
        }
    }

    #[test]
    fn barrier_completes() {
        for p in [1usize, 2, 3, 4, 8, 12] {
            // Barrier payload: 1 elem (pow2 rdoubling) or p elems (ring).
            let n = if p.is_power_of_two() { 1 } else { p };
            let progs = super::super::program::barrier(p);
            run(&progs, init_bufs(K::Barrier, p, n)).unwrap();
        }
    }
}

//! VGG-16 layer table (Simonyan & Zisserman 2014), 224×224 input.
//! 13 convs + 3 fcs; 138M parameters, ~90% of them in the fc layers —
//! the model where the paper's prioritization wins the most (the huge
//! fc6/fc7 gradients are issued FIRST in backprop and hog the wire).

use super::{conv, fc, pool, ModelDesc};

pub fn vgg16() -> ModelDesc {
    let mut l = Vec::new();
    // Block 1: 2×64 @224.
    l.push(conv("conv1_1", 3, 3, 64, 224, 224));
    l.push(conv("conv1_2", 3, 64, 64, 224, 224));
    l.push(pool("pool1", 64 * 112 * 112, (64 * 112 * 112) as f64));
    // Block 2: 2×128 @112.
    l.push(conv("conv2_1", 3, 64, 128, 112, 112));
    l.push(conv("conv2_2", 3, 128, 128, 112, 112));
    l.push(pool("pool2", 128 * 56 * 56, (128 * 56 * 56) as f64));
    // Block 3: 3×256 @56.
    l.push(conv("conv3_1", 3, 128, 256, 56, 56));
    l.push(conv("conv3_2", 3, 256, 256, 56, 56));
    l.push(conv("conv3_3", 3, 256, 256, 56, 56));
    l.push(pool("pool3", 256 * 28 * 28, (256 * 28 * 28) as f64));
    // Block 4: 3×512 @28.
    l.push(conv("conv4_1", 3, 256, 512, 28, 28));
    l.push(conv("conv4_2", 3, 512, 512, 28, 28));
    l.push(conv("conv4_3", 3, 512, 512, 28, 28));
    l.push(pool("pool4", 512 * 14 * 14, (512 * 14 * 14) as f64));
    // Block 5: 3×512 @14.
    l.push(conv("conv5_1", 3, 512, 512, 14, 14));
    l.push(conv("conv5_2", 3, 512, 512, 14, 14));
    l.push(conv("conv5_3", 3, 512, 512, 14, 14));
    l.push(pool("pool5", 512 * 7 * 7, (512 * 7 * 7) as f64));
    // Classifier.
    l.push(fc("fc6", 512 * 7 * 7, 4096));
    l.push(fc("fc7", 4096, 4096));
    l.push(fc("fc8", 4096, 1000));
    ModelDesc { name: "vgg16".into(), layers: l, default_batch: 32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_count_matches_paper() {
        let m = vgg16();
        let p = m.total_weight_elems() as f64;
        assert!((p - 138.3e6).abs() / 138.3e6 < 0.02, "{p}");
    }

    #[test]
    fn fc6_is_the_whale() {
        let m = vgg16();
        let fc6 = m.layers.iter().find(|l| l.name == "fc6").unwrap();
        assert!(fc6.weight_elems > 100_000_000);
    }
}

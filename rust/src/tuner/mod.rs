//! Measurement-driven collective selection — the autotuner.
//!
//! The analytic selector ([`crate::collectives::selector`]) predicts
//! algorithm crossovers from a closed-form two-tier alpha-beta model.
//! Das et al. (arXiv:1602.06709) and You et al. (arXiv:1708.02983) both
//! show those crossover points shift substantially with real fabric
//! latency/bandwidth ratios — measured tables beat closed forms once
//! topologies get real. We already own a cycle-accurate measuring
//! instrument (`simexec` over `NetSim`); this subsystem turns it into an
//! autotuner:
//!
//! * [`probe`] times every candidate algorithm for each tunable
//!   [`crate::collectives::CollectiveKind`] across a log-spaced
//!   (rank count × message size) grid by executing real chunk programs
//!   through the discrete-event fabric on the live topology — every
//!   cell on its own private fabric, so `--sim-threads n` stripes the
//!   grid across `n` workers ([`probe::tune_threaded`]) and still emits
//!   a byte-identical table (see `docs/ARCHITECTURE.md`);
//! * [`table`] persists the measurements as a [`TuningTable`] keyed by a
//!   topology *fingerprint*, with per-cell winners, crossover extraction
//!   and nearest-cell + log-interpolated lookup, serialized via
//!   [`crate::util::json`] (the `tune` CLI subcommand emits one, and
//!   `--tuning-table <path>` loads it back);
//! * [`policy`] exposes [`SelectionPolicy`] — `Analytic` (the default),
//!   `Tuned` and `TunedWithFallback` — threaded through the engine, the
//!   analytic design-space model and the CLI, so every algorithm choice
//!   goes through one switchable decision point.
//!
//! Every later topology feature calibrates against this bridge from
//! "model says" to "measurement says": the N-level tier stack (PR 4)
//! already does — the fingerprint hashes every tier's size and physics
//! (a two-tier table can never silently apply to a three-tier fabric),
//! the probe's rank grid covers tier-shaped rows, and multi-level
//! hierarchical candidates are measured like any other. Multi-rail NICs
//! ride the same path: the fingerprint hashes every level's rail
//! count (a table probed single-rail never silently applies to a
//! striped fabric — `TunedWithFallback` falls back to the analytic
//! model on mismatch), and the probe's size grid gains a rail dimension
//! (`ProbeSpec::size_grid_for` adds the whole-chunk stripe-transition
//! sizes where striping moves the measured crossovers).
//!
//! # Candidate-key grammar
//!
//! Since `v4`, allreduce candidates span **(algorithm ×
//! wire-precision)** — compression is a first-class selection dimension,
//! not a post-hoc override. A table cell's candidate keys read:
//!
//! * `ring`, `rdoubling`, `halving`, `hier:<g>[x<g>...]` — bare keys are
//!   fp32 wire (backward compatible with `v3`-era spellings);
//! * `ring@bf16`, `ring@int8`, `hier:8x128@bf16` — the same algorithm
//!   timed with its payloads encoded at the compressed width, the
//!   endpoint (de)quantize cost included
//!   ([`crate::collectives::selector::quant_chain_ns`]).
//!
//! [`table::cand_key`] / [`table::parse_cand_key`] implement the
//! grammar. Only reductions carry compressed columns
//! ([`probe::wire_menu`]): allgather and friends have no error-feedback
//! protection, so their cells stay fp32-only. The `v4` fingerprint bump
//! exists purely so an old reader never misparses a candidate key — the
//! hashed fields are unchanged from `v3`; with `--wire-dtype auto` the
//! engine answers (algorithm, wire) pairs straight from the table
//! ([`SelectionPolicy::choose_allreduce_wire`]), and `mlsl tune --out`
//! prints the measured size where each precision starts winning.

pub mod policy;
pub mod probe;
pub mod table;

pub use policy::{Contention, SelectionPolicy};
pub use probe::{tune, tune_threaded, ProbeSpec};
pub use table::{out_of_grid_count, Cand, TuningTable};

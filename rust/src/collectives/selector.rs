//! Size-adaptive algorithm selection — the paper's "implements performance
//! critical data path operations in an optimal manner".
//!
//! The choice is driven by a TWO-TIER alpha-beta cost model on the actual
//! fabric. With contiguous node grouping (node = rank / ranks_per_node), a
//! hop at partner distance d is intra-node when d < ranks_per_node and
//! inter-node otherwise; each tier has its own alpha (latency + overhead)
//! and beta⁻¹ (bandwidth):
//!
//! * ring allreduce:            2(P−1)·(α + (n/P)/B), gated by its slowest
//!   (inter-node) hops unless the whole ring fits in one node;
//! * recursive doubling:        Σ over rounds d of (α_d + n/B_d);
//! * halving-doubling:          Σ over rounds d of 2·(α_d + (n·d/P)/B_d);
//! * hierarchical:              2·⌈log₂ r⌉·(α_intra + n/B_intra) intra
//!   reduce+broadcast, plus a flat allreduce among the P/r node leaders
//!   whose hops are all inter-tier.
//!
//! Small n → latency term dominates → fewest rounds (recursive doubling).
//! Large n → bandwidth term dominates → ring / halving-doubling. Many
//! ranks per node → hierarchical (O(P/r) inter-node steps instead of
//! O(P)). On flat fabrics (ranks_per_node = 1) every formula collapses to
//! the classic single-tier model.

use super::Algorithm;
use crate::fabric::gbps_to_bytes_per_ns;
use crate::fabric::topology::{Tier, Topology};
use crate::Ns;

/// Per-message fixed cost of a tier (latency + injection overhead), ns.
fn alpha(topo: &Topology, tier: Tier) -> f64 {
    (topo.latency_of(tier) + topo.overhead_of(tier)) as f64
}

/// Bandwidth of a tier, bytes/ns.
fn bw(topo: &Topology, tier: Tier) -> f64 {
    gbps_to_bytes_per_ns(topo.gbps_of(tier))
}

/// Tier of an XOR-distance-`d` exchange under contiguous grouping. The
/// partner `r ^ d` provably stays in-node for d < ranks_per_node ONLY
/// when ranks_per_node is a power of two (node = rank >> log2(rpn));
/// otherwise be conservative and price the hop inter-node.
fn tier_at(d: usize, ranks_per_node: usize) -> Tier {
    if ranks_per_node.is_power_of_two() && d < ranks_per_node {
        Tier::Intra
    } else {
        Tier::Inter
    }
}

/// Predicted wall time (ns, unrounded) of a FLAT algorithm over `p` ranks
/// with hops priced via `tier_at(d, rpn)`. `rpn = 1` prices every hop at
/// the inter tier (used for the leader phase of hierarchical allreduce).
fn flat_cost(topo: &Topology, alg: Algorithm, p: usize, n: f64, rpn: usize) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            // Lockstep pipeline: each step is gated by its slowest hop —
            // inter-node unless the whole ring fits in one node.
            let t = if p <= rpn { Tier::Intra } else { Tier::Inter };
            2.0 * (pf - 1.0) * (alpha(topo, t) + n / pf / bw(topo, t))
        }
        Algorithm::RecursiveDoubling => {
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let t = tier_at(d, rpn);
                total += alpha(topo, t) + n / bw(topo, t);
                d <<= 1;
            }
            total
        }
        Algorithm::HalvingDoubling => {
            // Reduce-scatter halving + mirrored allgather doubling: the
            // round at partner distance d moves n·d/p bytes, twice.
            let mut total = 0.0;
            let mut d = p / 2;
            while d >= 1 {
                let t = tier_at(d, rpn);
                total += 2.0 * (alpha(topo, t) + n * d as f64 / pf / bw(topo, t));
                d /= 2;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Predicted wall time of an allreduce of `bytes` over `p` ranks.
pub fn predict_allreduce_ns(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> Ns {
    if p <= 1 {
        return 0;
    }
    let n = bytes as f64;
    let rpn = topo.ranks_per_node.max(1);
    let t = match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost(topo, alg, p, n, rpn)
        }
        Algorithm::Hierarchical { ranks_per_node } => {
            let r = ranks_per_node;
            if r == 0 || p % r != 0 {
                // Invalid grouping: never the cheapest choice.
                return Ns::MAX / 4;
            }
            let nodes = p / r;
            // Intra binomial reduce + broadcast: ⌈log₂ r⌉ full-buffer
            // rounds each, on the shared-memory tier.
            let intra = if r > 1 {
                let rounds = (r as f64).log2().ceil();
                2.0 * rounds * (alpha(topo, Tier::Intra) + n / bw(topo, Tier::Intra))
            } else {
                0.0
            };
            // Leaders sit on distinct nodes → every hop inter-tier. The
            // inner algorithm is exactly what program::build will emit.
            let inner = super::program::hierarchical_inner(nodes);
            let inter = if nodes > 1 { flat_cost(topo, inner, nodes, n, 1) } else { 0.0 };
            intra + inter
        }
        Algorithm::Auto => {
            let best = choose_algorithm(topo, p, bytes);
            return predict_allreduce_ns(topo, best, p, bytes);
        }
    };
    t.ceil() as Ns
}

/// Flat algorithms legal at this rank count.
fn flat_candidates(p: usize) -> Vec<Algorithm> {
    let mut c = vec![Algorithm::Ring];
    if p.is_power_of_two() {
        c.push(Algorithm::RecursiveDoubling);
        c.push(Algorithm::HalvingDoubling);
    }
    c
}

/// Every allreduce algorithm the selector considers at this (fabric, p).
/// Hierarchical is a candidate only when the topology is multi-rank-per-
/// node and its node size divides `p` (contiguous full-node communicator).
/// The tuning probe ([`crate::tuner::probe`]) measures EXACTLY this set,
/// so tuned tables and the analytic chooser pick from the same menu.
pub fn candidate_algorithms(topo: &Topology, p: usize) -> Vec<Algorithm> {
    if p <= 1 {
        return vec![Algorithm::Ring];
    }
    let rpn = topo.ranks_per_node;
    let mut candidates = flat_candidates(p);
    if rpn > 1 && p > rpn && p % rpn == 0 {
        candidates.push(Algorithm::Hierarchical { ranks_per_node: rpn });
    }
    candidates
}

/// Pick the cheapest supported algorithm for this (fabric, p, bytes).
pub fn choose_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *candidate_algorithms(topo, p)
        .iter()
        .min_by_key(|a| predict_allreduce_ns(topo, **a, p, bytes))
        .unwrap()
}

/// Like [`predict_allreduce_ns`] but pricing EVERY hop at the inter
/// tier. This is the correct model for communicators that do NOT occupy
/// contiguous ranks of the topology (e.g. the strided data-parallel
/// groups of a hybrid distribution): there, rank distance inside the
/// communicator says nothing about physical co-location, so the intra
/// discount must not apply.
pub fn predict_flat_inter_allreduce_ns(
    topo: &Topology,
    alg: Algorithm,
    p: usize,
    bytes: u64,
) -> Ns {
    if p <= 1 {
        return 0;
    }
    match alg {
        Algorithm::Ring | Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling => {
            flat_cost(topo, alg, p, bytes as f64, 1).ceil() as Ns
        }
        other => predict_allreduce_ns(topo, other, p, bytes),
    }
}

/// Like [`choose_algorithm`] but never hierarchical, and priced all
/// inter-tier — for communicators whose members do not decompose into
/// whole nodes (e.g. the strided data-parallel groups of a hybrid
/// distribution).
pub fn choose_flat_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *flat_candidates(p)
        .iter()
        .min_by_key(|a| predict_flat_inter_allreduce_ns(topo, **a, p, bytes))
        .unwrap()
}

// ---------------------------------------------------------------------------
// Allgather pricing (activation exchanges)
// ---------------------------------------------------------------------------

/// Allgather algorithms legal at this rank count: ring always; recursive
/// doubling (block-doubling allgather, same volume in log₂ p rounds) only
/// at power-of-two rank counts.
pub fn allgather_candidates(p: usize) -> Vec<Algorithm> {
    let mut c = vec![Algorithm::Ring];
    if p > 1 && p.is_power_of_two() {
        c.push(Algorithm::RecursiveDoubling);
    }
    c
}

/// Two-tier cost of a flat allgather of `n` total bytes over `p` ranks
/// (each rank contributes n/p); `rpn = 1` prices every hop inter-tier.
fn allgather_cost(topo: &Topology, alg: Algorithm, p: usize, n: f64, rpn: usize) -> f64 {
    let pf = p as f64;
    match alg {
        Algorithm::Ring => {
            // p−1 lockstep steps of n/p bytes, gated by the slowest hop.
            let t = if p <= rpn { Tier::Intra } else { Tier::Inter };
            (pf - 1.0) * (alpha(topo, t) + n / pf / bw(topo, t))
        }
        Algorithm::RecursiveDoubling if p.is_power_of_two() => {
            // The round at partner distance d exchanges the held block of
            // n·d/p bytes; total volume matches the ring in log₂ p rounds.
            let mut total = 0.0;
            let mut d = 1;
            while d < p {
                let t = tier_at(d, rpn);
                total += alpha(topo, t) + n * d as f64 / pf / bw(topo, t);
                d <<= 1;
            }
            total
        }
        _ => f64::INFINITY,
    }
}

/// Predicted wall time of an allgather of `bytes` (total buffer) over `p`
/// ranks, priced with the same two-tier model as allreduce.
pub fn predict_allgather_ns(topo: &Topology, alg: Algorithm, p: usize, bytes: u64) -> Ns {
    if p <= 1 {
        return 0;
    }
    if alg == Algorithm::Auto {
        let best = choose_allgather_algorithm(topo, p, bytes);
        return predict_allgather_ns(topo, best, p, bytes);
    }
    let rpn = topo.ranks_per_node.max(1);
    let t = allgather_cost(topo, alg, p, bytes as f64, rpn);
    if t.is_finite() {
        t.ceil() as Ns
    } else {
        Ns::MAX / 4
    }
}

/// Pick the cheapest allgather algorithm for this (fabric, p, bytes) over
/// a node-aligned (contiguous) communicator.
pub fn choose_allgather_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *allgather_candidates(p)
        .iter()
        .min_by_key(|a| predict_allgather_ns(topo, **a, p, bytes))
        .unwrap()
}

/// Like [`choose_allgather_algorithm`] but priced all inter-tier — for
/// communicators that do not decompose into whole nodes.
pub fn choose_flat_allgather_algorithm(topo: &Topology, p: usize, bytes: u64) -> Algorithm {
    if p <= 1 {
        return Algorithm::Ring;
    }
    *allgather_candidates(p)
        .iter()
        .min_by_key(|a| allgather_cost(topo, **a, p, bytes as f64, 1).ceil() as Ns)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_messages_pick_fewest_rounds() {
        let topo = Topology::eth_10g();
        // 4 KB over 64 ranks: latency-bound -> recursive doubling.
        assert_eq!(choose_algorithm(&topo, 64, 4 * 1024), Algorithm::RecursiveDoubling);
    }

    #[test]
    fn large_messages_pick_bandwidth_optimal() {
        let topo = Topology::eth_10g();
        let alg = choose_algorithm(&topo, 64, 256 << 20);
        assert!(
            matches!(alg, Algorithm::Ring | Algorithm::HalvingDoubling),
            "{alg:?}"
        );
    }

    #[test]
    fn non_pow2_always_ring() {
        let topo = Topology::omnipath_100g();
        assert_eq!(choose_algorithm(&topo, 6, 1024), Algorithm::Ring);
        assert_eq!(choose_algorithm(&topo, 100, 1 << 20), Algorithm::Ring);
    }

    #[test]
    fn non_pow2_never_selects_doubling_even_on_smp_fabrics() {
        // The power-of-two precondition must hold regardless of tiers.
        for topo in [
            Topology::eth_10g(),
            Topology::eth_10g_smp(2),
            Topology::eth_10g_smp(4),
            Topology::omnipath_100g_smp(2),
        ] {
            for p in [3usize, 6, 12, 24, 48, 96, 100] {
                for bytes in [256u64, 64 << 10, 1 << 20, 64 << 20] {
                    let alg = choose_algorithm(&topo, p, bytes);
                    assert!(
                        !matches!(
                            alg,
                            Algorithm::RecursiveDoubling | Algorithm::HalvingDoubling
                        ),
                        "{} p={p} bytes={bytes}: {alg:?}",
                        topo.name
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_requires_multirank_nodes() {
        // Flat fabrics must NEVER select hierarchical, at any size.
        for topo in [Topology::eth_10g(), Topology::eth_25g(), Topology::omnipath_100g()] {
            for p in [2usize, 6, 16, 64, 96, 256] {
                for bytes in [256u64, 64 << 10, 16 << 20, 256 << 20] {
                    let alg = choose_algorithm(&topo, p, bytes);
                    assert!(
                        !matches!(alg, Algorithm::Hierarchical { .. }),
                        "{} p={p} bytes={bytes}: {alg:?}",
                        topo.name
                    );
                }
            }
        }
    }

    #[test]
    fn hierarchical_requires_dividing_node_size() {
        let topo = Topology::eth_10g_smp(4);
        // p not a multiple of ranks_per_node: hierarchical is not legal.
        for p in [6usize, 13, 30] {
            for bytes in [1u64 << 10, 16 << 20] {
                let alg = choose_algorithm(&topo, p, bytes);
                assert!(!matches!(alg, Algorithm::Hierarchical { .. }), "p={p}: {alg:?}");
            }
        }
    }

    #[test]
    fn hierarchical_wins_on_smp_fabric_for_nonpow2_worlds() {
        // 96 ranks at 2/node on 10GbE: the only flat option is ring
        // (non-pow2); hierarchical halves the inter-node step count and
        // must win across sizes.
        let topo = Topology::eth_10g_smp(2);
        for bytes in [64u64 << 10, 1 << 20, 16 << 20] {
            let alg = choose_algorithm(&topo, 96, bytes);
            assert_eq!(alg, Algorithm::Hierarchical { ranks_per_node: 2 }, "bytes={bytes}");
            let flat = predict_allreduce_ns(&topo, Algorithm::Ring, 96, bytes);
            let hier = predict_allreduce_ns(&topo, alg, 96, bytes);
            assert!(hier < flat, "bytes={bytes}: hier={hier} flat={flat}");
        }
    }

    #[test]
    fn strided_pricing_never_gets_the_intra_discount() {
        // A strided communicator's hops all cross nodes: the all-inter
        // model must agree with the flat fabric (identical NIC params)…
        let smp = Topology::eth_10g_smp(4);
        let flat = Topology::eth_10g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            for p in [4usize, 8, 16] {
                for bytes in [1u64 << 10, 1 << 20] {
                    assert_eq!(
                        predict_flat_inter_allreduce_ns(&smp, alg, p, bytes),
                        predict_allreduce_ns(&flat, alg, p, bytes),
                        "{alg:?} p={p} bytes={bytes}"
                    );
                }
            }
        }
        // …while the contiguous model rightly discounts a ring that fits
        // inside one node. The strided model must not inherit that.
        let b = 1u64 << 20;
        assert!(
            predict_flat_inter_allreduce_ns(&smp, Algorithm::Ring, 4, b)
                > predict_allreduce_ns(&smp, Algorithm::Ring, 4, b)
        );
    }

    #[test]
    fn non_pow2_node_sizes_price_doubling_rounds_inter() {
        // With 3 ranks/node the XOR partner at distance 1 or 2 can cross
        // a node boundary, so the contiguous model must fall back to
        // inter pricing — identical to the flat fabric.
        let smp = Topology::eth_10g_smp(3);
        let flat = Topology::eth_10g();
        for alg in [Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            assert_eq!(
                predict_allreduce_ns(&smp, alg, 16, 1 << 20),
                predict_allreduce_ns(&flat, alg, 16, 1 << 20),
                "{alg:?}"
            );
        }
    }

    #[test]
    fn choose_flat_never_returns_hierarchical() {
        let topo = Topology::eth_10g_smp(4);
        for p in [8usize, 64, 96] {
            for bytes in [1u64 << 10, 16 << 20] {
                let alg = choose_flat_algorithm(&topo, p, bytes);
                assert!(!matches!(alg, Algorithm::Hierarchical { .. }), "p={p}: {alg:?}");
            }
        }
    }

    #[test]
    fn hierarchical_prediction_counts_both_tiers() {
        let topo = Topology::eth_10g_smp(2);
        let bytes = 1u64 << 20;
        let hier = predict_allreduce_ns(
            &topo,
            Algorithm::Hierarchical { ranks_per_node: 2 },
            64,
            bytes,
        );
        // Must exceed the leaders-only flat phase (32 inter ranks)...
        let leaders_only = predict_allreduce_ns(&topo, Algorithm::HalvingDoubling, 32, bytes);
        assert!(hier > leaders_only, "hier={hier} leaders={leaders_only}");
        // ...but stay below the same algorithm run flat over all 64 ranks
        // on the inter tier (the whole point of the hierarchy).
        let flat_ring = predict_allreduce_ns(&topo, Algorithm::Ring, 64, bytes);
        assert!(hier < flat_ring, "hier={hier} flat_ring={flat_ring}");
    }

    #[test]
    fn invalid_hierarchical_grouping_is_never_cheapest() {
        let topo = Topology::eth_10g_smp(2);
        let cost =
            predict_allreduce_ns(&topo, Algorithm::Hierarchical { ranks_per_node: 5 }, 8, 1024);
        assert!(cost > predict_allreduce_ns(&topo, Algorithm::Ring, 8, 1024));
    }

    #[test]
    fn prediction_monotone_in_size() {
        let topo = Topology::omnipath_100g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling, Algorithm::HalvingDoubling] {
            let a = predict_allreduce_ns(&topo, alg, 16, 1 << 10);
            let b = predict_allreduce_ns(&topo, alg, 16, 1 << 24);
            assert!(b > a, "{alg:?}");
        }
    }

    #[test]
    fn single_rank_is_free() {
        let topo = Topology::eth_10g();
        assert_eq!(predict_allreduce_ns(&topo, Algorithm::Auto, 1, 1 << 20), 0);
    }

    #[test]
    fn crossover_exists() {
        // Sweeping sizes must switch algorithms somewhere (the A4 bench
        // regenerates the full crossover table).
        let topo = Topology::eth_10g();
        let small = choose_algorithm(&topo, 32, 1024);
        let large = choose_algorithm(&topo, 32, 64 << 20);
        assert_ne!(small, large);
    }

    #[test]
    fn allgather_rdoubling_wins_at_pow2_ring_otherwise() {
        let topo = Topology::eth_10g();
        // Same volume, fewer latency rounds: rd must win for p > 2…
        for bytes in [1u64 << 10, 1 << 20, 64 << 20] {
            assert_eq!(
                choose_allgather_algorithm(&topo, 32, bytes),
                Algorithm::RecursiveDoubling,
                "bytes={bytes}"
            );
        }
        // …and non-power-of-two rank counts only have the ring.
        for p in [3usize, 6, 12, 100] {
            assert_eq!(choose_allgather_algorithm(&topo, p, 1 << 20), Algorithm::Ring, "p={p}");
        }
    }

    #[test]
    fn allgather_prediction_monotone_and_tier_aware() {
        let topo = Topology::omnipath_100g();
        for alg in [Algorithm::Ring, Algorithm::RecursiveDoubling] {
            let a = predict_allgather_ns(&topo, alg, 16, 1 << 10);
            let b = predict_allgather_ns(&topo, alg, 16, 1 << 24);
            assert!(b > a, "{alg:?}");
        }
        // A 4-rank ring inside one node rides the intra tier; the flat
        // (all-inter) pricing must not inherit that discount.
        let smp = Topology::eth_10g_smp(4);
        let intra = predict_allgather_ns(&smp, Algorithm::Ring, 4, 1 << 20);
        let flat = predict_allgather_ns(&Topology::eth_10g(), Algorithm::Ring, 4, 1 << 20);
        assert!(intra < flat / 10, "intra={intra} flat={flat}");
        assert_eq!(choose_flat_allgather_algorithm(&smp, 6, 1 << 20), Algorithm::Ring);
    }

    #[test]
    fn candidate_sets_match_chooser_support() {
        let smp = Topology::eth_10g_smp(2);
        assert!(candidate_algorithms(&smp, 8)
            .contains(&Algorithm::Hierarchical { ranks_per_node: 2 }));
        assert!(!candidate_algorithms(&Topology::eth_10g(), 8)
            .iter()
            .any(|a| matches!(a, Algorithm::Hierarchical { .. })));
        assert_eq!(candidate_algorithms(&smp, 1), vec![Algorithm::Ring]);
        assert_eq!(allgather_candidates(6), vec![Algorithm::Ring]);
        assert_eq!(
            allgather_candidates(8),
            vec![Algorithm::Ring, Algorithm::RecursiveDoubling]
        );
    }

    #[test]
    fn crossover_point_is_ordered() {
        // Walking up the sizes on one fabric, once the choice leaves
        // RecursiveDoubling it never comes back (the cost curves cross
        // exactly once: rounds·n/B grows strictly faster than the
        // bandwidth-optimal 2(P−1)/P·n/B term).
        let topo = Topology::eth_10g();
        let mut left_rd = false;
        for shift in 6..28 {
            let alg = choose_algorithm(&topo, 32, 1u64 << shift);
            if alg != Algorithm::RecursiveDoubling {
                left_rd = true;
            } else {
                assert!(!left_rd, "RD re-selected at 2^{shift} after crossover");
            }
        }
        assert!(left_rd, "no crossover up to 2^27");
    }
}

//! Critical-path analysis over a recorded [`Trace`]: which hop/compute
//! chain determined a collective's finish time, and where along that
//! chain the nanoseconds went.
//!
//! The walk starts from the hop that delivered last for the collective
//! (ties broken by content order, so the result is deterministic) and
//! follows each span's [`Cause`] backwards — the event its sender was
//! reacting to — until it reaches a span posted up front. Each hop on
//! the path is decomposed into:
//!
//! * **queue** — posted until a wire first served it ([`HopSpan::queue_ns`]);
//! * **service** — pure egress of the max-cost piece (overhead + bytes/bw);
//! * **stall** — extra wire-holding time from preemption, gating or
//!   zero-bandwidth chaos windows ([`HopSpan::stall_ns`]);
//! * **flight** — post-egress latency (alpha, chaos-stretched)
//!   ([`HopSpan::flight_ns`]).
//!
//! Compute spans on the path contribute their full duration. Per-tier
//! attribution sums each hop's end-to-end time under its pricing level,
//! which is how the a6 hierarchical workload's leader-phase inter-tier
//! bottleneck shows up at large message sizes (`a12_trace_overhead`).

use std::collections::{BTreeMap, HashMap};

use super::{Cause, ComputeSpan, HopSpan, Trace, TraceEvent};
use crate::Ns;

/// One hop on the critical path with its time decomposition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PathStep {
    pub hop: HopSpan,
    pub queue_ns: Ns,
    pub service_ns: Ns,
    pub stall_ns: Ns,
    pub flight_ns: Ns,
}

/// The resolved critical path of one collective.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CriticalPath {
    pub coll_id: u64,
    /// Delivery time of the finishing hop.
    pub finish_ns: Ns,
    /// Hops in causal (time-ascending) order, finishing hop last.
    pub steps: Vec<PathStep>,
    pub queue_ns: Ns,
    pub service_ns: Ns,
    pub stall_ns: Ns,
    pub flight_ns: Ns,
    /// Compute time interleaved on the path.
    pub compute_ns: Ns,
    /// Per-tier end-to-end hop time (level → ns).
    pub by_level: BTreeMap<usize, Ns>,
}

impl CriticalPath {
    /// Summed hop end-to-end time on the path.
    pub fn hop_ns(&self) -> Ns {
        self.queue_ns + self.service_ns + self.stall_ns + self.flight_ns
    }

    /// Fraction of path hop time spent on tier `level`.
    pub fn level_share(&self, level: usize) -> f64 {
        let total: Ns = self.by_level.values().sum();
        if total == 0 {
            return 0.0;
        }
        self.by_level.get(&level).copied().unwrap_or(0) as f64 / total as f64
    }

    /// Human summary plus the top-`k` most expensive hops. The first
    /// line (`critical path: ...`) is grep-stable for CI smokes.
    pub fn render(&self, k: usize) -> String {
        let hop = self.hop_ns().max(1) as f64;
        let pct = |ns: Ns| format!("{:.0}%", ns as f64 * 100.0 / hop);
        let mut out = format!(
            "critical path: coll {} finish {} ns, {} hops (queue {} service {} stall {} flight {}), compute {} ns\n",
            self.coll_id,
            self.finish_ns,
            self.steps.len(),
            pct(self.queue_ns),
            pct(self.service_ns),
            pct(self.stall_ns),
            pct(self.flight_ns),
            self.compute_ns,
        );
        let tiers: Vec<String> = self
            .by_level
            .iter()
            .map(|(l, ns)| format!("tier {l}: {ns} ns ({:.0}%)", self.level_share(*l) * 100.0))
            .collect();
        out.push_str(&format!("  per-tier: {}\n", tiers.join("  ")));
        let mut ranked: Vec<&PathStep> = self.steps.iter().collect();
        ranked.sort_by_key(|s| std::cmp::Reverse(s.hop.total_ns()));
        for (i, s) in ranked.iter().take(k).enumerate() {
            out.push_str(&format!(
                "  #{:<2} {}->{} {} B prio {} tier {} [{}..{}] queue {} service {} stall {} flight {}\n",
                i + 1,
                s.hop.src,
                s.hop.dst,
                s.hop.bytes,
                s.hop.priority,
                s.hop.level,
                s.hop.posted_at,
                s.hop.deliver_at,
                s.queue_ns,
                s.service_ns,
                s.stall_ns,
                s.flight_ns,
            ));
        }
        out
    }
}

/// Walk the cause chain backwards from the hop that finished `coll_id`.
/// Returns `None` when the trace holds no hop tagged with `coll_id`.
pub fn critical_path(trace: &Trace, coll_id: u64) -> Option<CriticalPath> {
    // Content-identity indexes. Delivery/completion identities are
    // unique in a valid trace; ties (two identical messages delivered
    // at the same instant) resolve to the later-sorting span, the same
    // on serial and merged traces.
    let mut by_delivery: HashMap<Cause, &HopSpan> = HashMap::new();
    let mut by_compute: HashMap<Cause, &ComputeSpan> = HashMap::new();
    let mut target: Option<&HopSpan> = None;
    for ev in &trace.events {
        match ev {
            TraceEvent::Hop(h) => {
                by_delivery.insert(
                    Cause::Msg {
                        at: h.deliver_at,
                        src: h.src,
                        dst: h.dst,
                        bytes: h.bytes,
                        priority: h.priority,
                        tag: h.tag,
                    },
                    h,
                );
                let better = match target {
                    None => true,
                    Some(t) => (h.deliver_at, h) > (t.deliver_at, t),
                };
                if h.tag == coll_id && better {
                    target = Some(h);
                }
            }
            TraceEvent::Compute(c) => {
                by_compute
                    .insert(Cause::Compute { at: c.end, node: c.node, tag: c.tag }, c);
            }
            _ => {}
        }
    }
    let target = target?;
    let mut steps: Vec<PathStep> = Vec::new();
    let mut compute_ns: Ns = 0;
    let mut by_level: BTreeMap<usize, Ns> = BTreeMap::new();
    let mut cur = target;
    // Cycle guard: causes strictly precede their spans in time, so the
    // chain is finite; the cap is belt and braces for malformed traces.
    for _ in 0..1_000_000 {
        steps.push(PathStep {
            hop: cur.clone(),
            queue_ns: cur.queue_ns(),
            service_ns: cur.service_ns,
            stall_ns: cur.stall_ns(),
            flight_ns: cur.flight_ns(),
        });
        *by_level.entry(cur.level).or_insert(0) += cur.total_ns();
        // Follow compute links until the next message dependency.
        let mut cause = cur.cause;
        loop {
            match cause {
                Some(c @ Cause::Compute { .. }) => match by_compute.get(&c) {
                    Some(span) => {
                        compute_ns += span.end.saturating_sub(span.start);
                        cause = span.cause;
                    }
                    None => {
                        cause = None;
                    }
                },
                _ => break,
            }
        }
        match cause.and_then(|c| by_delivery.get(&c)) {
            Some(&prev) if prev.deliver_at <= cur.posted_at => cur = prev,
            _ => break,
        }
    }
    steps.reverse();
    let sum = |f: fn(&PathStep) -> Ns| -> Ns { steps.iter().map(f).sum() };
    Some(CriticalPath {
        coll_id,
        finish_ns: target.deliver_at,
        queue_ns: sum(|s| s.queue_ns),
        service_ns: sum(|s| s.service_ns),
        stall_ns: sum(|s| s.stall_ns),
        flight_ns: sum(|s| s.flight_ns),
        compute_ns,
        by_level,
        steps,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(
        src: usize,
        dst: usize,
        posted: Ns,
        deliver: Ns,
        level: usize,
        tag: u64,
        cause: Option<Cause>,
    ) -> HopSpan {
        HopSpan {
            src,
            dst,
            bytes: 1 << 10,
            priority: 1,
            tag,
            level,
            posted_at: posted,
            first_service_at: posted + 5,
            egress_done_at: deliver - 20,
            deliver_at: deliver,
            service_ns: deliver - posted - 40,
            pieces: 1,
            lat_mult_milli: 1000,
            cause,
        }
    }

    fn msg_cause(h: &HopSpan) -> Cause {
        Cause::Msg {
            at: h.deliver_at,
            src: h.src,
            dst: h.dst,
            bytes: h.bytes,
            priority: h.priority,
            tag: h.tag,
        }
    }

    #[test]
    fn walks_the_chain_and_decomposes() {
        // 0→1 at [0,100], then 1→2 at [100,250], then 2→3 at [250,500].
        let h0 = hop(0, 1, 0, 100, 0, 1, None);
        let h1 = hop(1, 2, 100, 250, 1, 1, Some(msg_cause(&h0)));
        let h2 = hop(2, 3, 250, 500, 1, 1, Some(msg_cause(&h1)));
        // A red-herring earlier delivery of the same collective.
        let other = hop(3, 0, 0, 90, 0, 1, None);
        let tr = Trace {
            events: vec![
                TraceEvent::Hop(h1.clone()),
                TraceEvent::Hop(h0.clone()),
                TraceEvent::Hop(other),
                TraceEvent::Hop(h2.clone()),
            ],
        }
        .normalized();
        let cp = critical_path(&tr, 1).unwrap();
        assert_eq!(cp.finish_ns, 500);
        assert_eq!(cp.steps.len(), 3);
        assert_eq!(cp.steps[0].hop, h0);
        assert_eq!(cp.steps[2].hop, h2);
        // Decomposition sums to the hops' end-to-end time.
        assert_eq!(cp.hop_ns(), 100 + 150 + 250);
        assert_eq!(cp.by_level.get(&0), Some(&100));
        assert_eq!(cp.by_level.get(&1), Some(&400));
        assert!((cp.level_share(1) - 0.8).abs() < 1e-12);
        let txt = cp.render(2);
        assert!(txt.starts_with("critical path: coll 1 finish 500 ns, 3 hops"));
        assert!(txt.contains("per-tier"));
        assert_eq!(critical_path(&tr, 99), None);
    }

    #[test]
    fn compute_links_bridge_message_dependencies() {
        let h0 = hop(0, 1, 0, 100, 0, 2, None);
        let comp = ComputeSpan {
            node: 1,
            start: 100,
            end: 180,
            tag: 7,
            cause: Some(msg_cause(&h0)),
        };
        let h1 = hop(
            1,
            2,
            180,
            300,
            0,
            2,
            Some(Cause::Compute { at: 180, node: 1, tag: 7 }),
        );
        let tr = Trace {
            events: vec![
                TraceEvent::Hop(h0.clone()),
                TraceEvent::Compute(comp),
                TraceEvent::Hop(h1),
            ],
        };
        let cp = critical_path(&tr, 2).unwrap();
        assert_eq!(cp.steps.len(), 2, "compute links bridge to the prior hop");
        assert_eq!(cp.compute_ns, 80);
        assert_eq!(cp.steps[0].hop, h0);
    }
}

//! Fabric + node parameter presets for the paper's testbeds.
//!
//! Numbers are public-spec-derived, not measured on the authors' clusters;
//! EXPERIMENTS.md compares *shapes* (who wins, by what factor), which these
//! presets preserve (10GbE: high latency + low bandwidth → prioritization
//! matters most; Omnipath: low latency + high bandwidth → near-ideal
//! scaling with overlap).

use crate::Ns;

/// Network fabric parameters (the alpha–beta–gamma model).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub name: String,
    /// Per-NIC egress line rate, Gbit/s (beta⁻¹).
    pub link_gbps: f64,
    /// End-to-end message latency, ns (alpha): propagation + switching.
    pub latency_ns: Ns,
    /// Per-message software/NIC injection overhead, ns (gamma). Paid on
    /// the egress wire before the first byte moves — this is what makes
    /// small messages latency-bound and motivates prioritization.
    pub per_msg_overhead_ns: Ns,
    /// Chunk size collectives use on this fabric, bytes. Preemption is
    /// chunk-granular, so this is also the preemption latency knob.
    pub chunk_bytes: u64,
}

impl Topology {
    /// 10 Gbit/s Ethernet, TCP-class latency — the fabric of the paper's
    /// 1.8–2.2× prioritization result (C1).
    pub fn eth_10g() -> Self {
        Self {
            name: "eth10g".into(),
            link_gbps: 10.0,
            latency_ns: 30_000,          // ~30 µs TCP/Ethernet stack
            per_msg_overhead_ns: 4_000,  // kernel/NIC doorbell path
            chunk_bytes: 256 * 1024,
        }
    }

    /// Intel Omnipath-class 100 Gbit/s HPC fabric — Fig. 2's testbed.
    pub fn omnipath_100g() -> Self {
        Self {
            name: "omnipath100g".into(),
            link_gbps: 100.0,
            latency_ns: 1_100,          // ~1.1 µs MPI pingpong
            per_msg_overhead_ns: 250,
            chunk_bytes: 1024 * 1024,
        }
    }

    /// 25 GbE cloud fabric (intermediate point, used in ablations).
    pub fn eth_25g() -> Self {
        Self {
            name: "eth25g".into(),
            link_gbps: 25.0,
            latency_ns: 15_000,
            per_msg_overhead_ns: 2_000,
            chunk_bytes: 512 * 1024,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "eth10g" => Some(Self::eth_10g()),
            "eth25g" => Some(Self::eth_25g()),
            "omnipath100g" | "opa" => Some(Self::omnipath_100g()),
            _ => None,
        }
    }

    /// Pure wire time for `bytes` (no latency/overhead).
    pub fn wire_ns(&self, bytes: u64) -> Ns {
        super::wire_ns(bytes, self.link_gbps)
    }

    /// Full cost of a single point-to-point message of `bytes`.
    pub fn msg_ns(&self, bytes: u64) -> Ns {
        self.per_msg_overhead_ns + self.wire_ns(bytes) + self.latency_ns
    }
}

/// Node compute model (Skylake-class by default).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    /// Peak single-precision FLOP/s of the whole socket pair.
    pub peak_flops: f64,
    /// Fraction of peak a tuned DL framework sustains (conv/gemm mix).
    pub dl_efficiency: f64,
    /// Physical cores (comm cores are stolen from these).
    pub cores: usize,
}

impl NodeSpec {
    /// 2× Intel Xeon Gold 6148 (Skylake, the paper's node): 2 × 20 cores ×
    /// 2 AVX-512 FMA units × 16 f32 lanes × 2 flop × 2.4 GHz ≈ 6.1 Tf/s.
    pub fn skylake_6148() -> Self {
        Self {
            name: "2xXeon6148".into(),
            peak_flops: 6.1e12,
            dl_efficiency: 0.55,
            cores: 40,
        }
    }

    /// Xeon Phi 7250 (the 9600-node Cori run cited by the paper).
    pub fn xeon_phi_7250() -> Self {
        Self {
            name: "XeonPhi7250".into(),
            peak_flops: 6.0e12,
            dl_efficiency: 0.35,
            cores: 68,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "skylake" | "2xXeon6148" => Some(Self::skylake_6148()),
            "knl" | "XeonPhi7250" => Some(Self::xeon_phi_7250()),
            _ => None,
        }
    }

    /// Sustained FLOP/s with `comm_cores` dedicated to driving the network
    /// (the paper: "dedicating one or more cores for driving the network").
    pub fn effective_flops(&self, comm_cores: usize) -> f64 {
        let compute_cores = self.cores.saturating_sub(comm_cores).max(1);
        self.peak_flops * self.dl_efficiency * compute_cores as f64 / self.cores as f64
    }

    /// Time to execute `flops` floating point ops, ns.
    pub fn compute_ns(&self, flops: f64, comm_cores: usize) -> Ns {
        (flops / self.effective_flops(comm_cores) * 1e9).ceil() as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scales_linearly() {
        let t = Topology::eth_10g();
        // 10 Gbps = 1.25 B/ns -> 1 MiB takes 1048576/1.25 ≈ 838861 ns.
        assert_eq!(t.wire_ns(1_048_576), 838_861);
        assert!(t.wire_ns(2 * 1_048_576) >= 2 * t.wire_ns(1_048_576) - 1);
    }

    #[test]
    fn omnipath_beats_ethernet() {
        let e = Topology::eth_10g();
        let o = Topology::omnipath_100g();
        assert!(o.msg_ns(1024) < e.msg_ns(1024));
        assert!(o.msg_ns(16 << 20) < e.msg_ns(16 << 20));
    }

    #[test]
    fn comm_cores_reduce_compute_rate() {
        let n = NodeSpec::skylake_6148();
        assert!(n.effective_flops(2) < n.effective_flops(0));
        // Stealing 2 of 40 cores costs 5%.
        let ratio = n.effective_flops(2) / n.effective_flops(0);
        assert!((ratio - 38.0 / 40.0).abs() < 1e-9);
    }

    #[test]
    fn presets_resolve_by_name() {
        assert!(Topology::by_name("eth10g").is_some());
        assert!(Topology::by_name("opa").is_some());
        assert!(Topology::by_name("nope").is_none());
        assert!(NodeSpec::by_name("skylake").is_some());
    }
}

"""L2 model tests: shapes, decomposition equivalence, trainability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.presets import PRESETS, n_params

CFG = PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return model.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tokens():
    key = jax.random.PRNGKey(1)
    return jax.random.randint(key, (CFG.batch, CFG.seq_len + 1), 0, CFG.vocab)


def test_param_specs_order_and_count(params):
    specs = model.param_specs(CFG)
    assert len(specs) == len(params)
    assert specs[0]["name"] == "tok_emb" and specs[0]["layer"] == 0
    assert specs[-1]["name"] == "w_out"
    # fwd_order is the list position (the allreduce priority class).
    for i, s in enumerate(specs):
        assert s["fwd_order"] == i
    # layer indices are non-decreasing through the forward pass.
    layers = [s["layer"] for s in specs]
    assert layers == sorted(layers)
    assert sum(s["size"] for s in specs) == n_params(CFG)


def test_forward_shape(params, tokens):
    logits = model.forward(CFG, params, tokens[:, :-1])
    assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)
    assert jnp.isfinite(logits).all()


def test_loss_near_uniform_at_init(params, tokens):
    loss = model.loss_fn(CFG, params, tokens)
    # Small-init network ~ uniform predictions: loss ~ log(vocab).
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_grad_step_outputs(params, tokens):
    out = model.grad_step(CFG, *params, tokens)
    loss, grads = out[0], out[1:]
    assert loss.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert jnp.isfinite(g).all()


def test_train_step_equals_grad_plus_update(params, tokens):
    """The decomposed path (grad_step -> allreduce(1 rank) -> apply_update)
    must be bit-compatible with the fused train_step — this is the invariant
    the Rust data-parallel trainer relies on."""
    n = len(params)
    moms = [jnp.zeros_like(p) for p in params]
    lr, mu, wd = 3e-2, 0.9, 1e-4

    fused = model.train_step(CFG, lr, mu, wd, *params, *moms, tokens)
    fp, fm, floss = fused[:n], fused[n:2 * n], fused[2 * n]

    out = model.grad_step(CFG, *params, tokens)
    gloss, grads = out[0], out[1:]
    upd = model.apply_update(CFG, lr, mu, wd, *params, *moms, *grads)
    up, um = upd[:n], upd[n:]

    np.testing.assert_allclose(float(floss), float(gloss), rtol=1e-6)
    for a, c in zip(fp, up):
        np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-7)
    for a, c in zip(fm, um):
        np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-7)


def test_loss_decreases_over_steps(params, tokens):
    """A few SGD steps on one batch must reduce the loss (trainability)."""
    n = len(params)
    ps = list(params)
    moms = [jnp.zeros_like(p) for p in ps]
    losses = []
    for _ in range(5):
        out = model.train_step(CFG, 0.1, 0.9, 0.0, *ps, *moms, tokens)
        ps, moms, loss = list(out[:n]), list(out[n:2 * n]), out[2 * n]
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_causality(params):
    """Changing future tokens must not change past logits."""
    t1 = jnp.zeros((1, CFG.seq_len), jnp.int32)
    t2 = t1.at[0, -1].set(3)
    l1 = model.forward(CFG, params, t1)
    l2 = model.forward(CFG, params, t2)
    np.testing.assert_allclose(l1[0, :-1], l2[0, :-1], rtol=1e-5, atol=1e-6)

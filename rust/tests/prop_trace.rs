//! Property tests for the deterministic trace layer (`mlsl::trace`):
//! observation is never allowed to change the physics, and partitioning
//! is never allowed to change the observation.
//!
//! For random topologies, collective builders, sizes, chaos plans and
//! shard/thread grids:
//!
//! * **Merge identity** — the merged per-shard trace of a partitioned
//!   run is byte-identical to the serial run's normalized trace (same
//!   `Vec<TraceEvent>`, element for element);
//! * **Heisenberg check** — turning tracing ON leaves the
//!   delivered-message multiset, per-rank completions, finish time,
//!   final clock, traffic stats and chaos counters byte-identical to a
//!   traced-off run.
//!
//! See `docs/TRACING.md` for the content-identity design that makes the
//! first property exact rather than approximate.

use mlsl::collectives::parexec::{run_collective, run_collective_serial, FleetConfig};
use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::{Algorithm as A, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::ChaosPlan;
use mlsl::trace::TraceEvent;
use mlsl::util::proptest::{run as prop_run, Config};

/// Random test fabric: flat, smp, multi-rail or racked — trace records
/// must merge exactly across all tier shapes.
fn random_topo(pick: usize) -> Topology {
    match pick % 4 {
        0 => Topology::flat("trtest", 8.0, 1_000, 100, 1 << 20),
        1 => Topology::by_name("eth10g-x2").unwrap(),
        2 => Topology::by_name("eth10g-x2e2").unwrap(),
        _ => Topology::by_name("eth10g-x2r4").unwrap(),
    }
}

#[test]
fn prop_merged_partitioned_trace_equals_serial_trace() {
    prop_run(
        Config { cases: 40, seed: 101 },
        |r| {
            let topo_pick = r.usize_below(4);
            let p = 2 + r.usize_below(31); // 2..33
            let n = 1 + r.usize_below(2_000);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                A::RecursiveDoubling
            } else {
                A::Ring
            };
            let kind = if r.below(2) == 0 {
                CollectiveKind::Allreduce
            } else {
                CollectiveKind::Allgather
            };
            let chaos_seed = if r.below(2) == 0 { Some(r.below(u64::MAX)) } else { None };
            let shards = 2 + r.usize_below(3); // 2..=4
            let threads = [1usize, 2, 4][r.usize_below(3)];
            (topo_pick, p, n, kind, alg, chaos_seed, shards, threads)
        },
        |&(topo_pick, p, n, kind, alg, chaos_seed, shards, threads)| {
            let topo = random_topo(topo_pick);
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            let chaos = chaos_seed.map(|s| ChaosPlan::generate(s, &topo, p, 2_000_000));
            let label = format!(
                "{kind:?}/{alg} p={p} n={n} topo={} chaos={chaos_seed:?} \
                 shards={shards} threads={threads}",
                topo.name
            );
            let serial = run_collective_serial(
                &topo,
                p,
                progs.clone(),
                WireDtype::F32,
                1,
                chaos.as_ref(),
                false,
                true,
            );
            let st = serial.trace.as_ref().expect("tracing was on");
            if st.span_count() == 0 {
                return Err(format!("{label}: serial trace is empty"));
            }
            // Exactly one RankDone per rank, regardless of partitioning.
            let dones = st
                .events
                .iter()
                .filter(|e| matches!(e, TraceEvent::RankDone { .. }))
                .count();
            if dones != p {
                return Err(format!("{label}: {dones} RankDone records, want {p}"));
            }
            let cfg = FleetConfig { shards, threads, chaos, record_deliveries: false, trace: true };
            let par = run_collective(&topo, p, progs.clone(), WireDtype::F32, 1, &cfg);
            if par.trace.as_ref() != serial.trace.as_ref() {
                let pt = par.trace.as_ref().map(|t| t.span_count()).unwrap_or(0);
                return Err(format!(
                    "{label}: merged trace diverged ({} vs {} spans)",
                    pt,
                    st.span_count()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_tracing_never_perturbs_the_simulation() {
    prop_run(
        Config { cases: 40, seed: 102 },
        |r| {
            let topo_pick = r.usize_below(4);
            let p = 2 + r.usize_below(31);
            let n = 1 + r.usize_below(2_000);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                A::RecursiveDoubling
            } else {
                A::Ring
            };
            let kind = if r.below(2) == 0 {
                CollectiveKind::Allreduce
            } else {
                CollectiveKind::Allgather
            };
            let chaos_seed = if r.below(2) == 0 { Some(r.below(u64::MAX)) } else { None };
            let shards = 1 + r.usize_below(4); // 1..=4 (1 = serial shape)
            (topo_pick, p, n, kind, alg, chaos_seed, shards)
        },
        |&(topo_pick, p, n, kind, alg, chaos_seed, shards)| {
            let topo = random_topo(topo_pick);
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            let chaos = chaos_seed.map(|s| ChaosPlan::generate(s, &topo, p, 2_000_000));
            let label = format!(
                "{kind:?}/{alg} p={p} n={n} topo={} chaos={chaos_seed:?} shards={shards}",
                topo.name
            );
            let run = |trace: bool| {
                let cfg = FleetConfig {
                    shards,
                    threads: 1,
                    chaos: chaos.clone(),
                    record_deliveries: true,
                    trace,
                };
                run_collective(&topo, p, progs.clone(), WireDtype::F32, 1, &cfg)
            };
            let off = run(false);
            let on = run(true);
            if off.trace.is_some() {
                return Err(format!("{label}: untraced run produced a trace"));
            }
            if on.trace.as_ref().map(|t| t.span_count()).unwrap_or(0) == 0 {
                return Err(format!("{label}: traced run produced no spans"));
            }
            if on.delivered != off.delivered {
                return Err(format!("{label}: tracing changed the delivered multiset"));
            }
            if on.completions != off.completions
                || on.finish_ns != off.finish_ns
                || on.final_clock != off.final_clock
            {
                return Err(format!(
                    "{label}: tracing changed timing (finish {} vs {})",
                    on.finish_ns, off.finish_ns
                ));
            }
            if on.stats.msgs_sent != off.stats.msgs_sent
                || on.stats.bytes_sent != off.stats.bytes_sent
                || on.stats.bytes_by_priority != off.stats.bytes_by_priority
                || on.stats.preemptions != off.stats.preemptions
            {
                return Err(format!("{label}: tracing changed traffic stats"));
            }
            if on.chaos != off.chaos {
                return Err(format!("{label}: tracing changed chaos counters"));
            }
            Ok(())
        },
    );
}

//! Engine run reports: steady-state iteration time, exposed communication,
//! scaling efficiency helpers.

use crate::engine::EngineConfig;
use crate::fabric::NetSim;
use crate::metrics::Timeline;
use crate::trace::Trace;
use crate::Ns;

/// Result of a simulated training run.
#[derive(Debug, Clone)]
pub struct Report {
    /// Steady-state iteration time (warmup iteration excluded), averaged
    /// over nodes and measured iterations.
    pub iter_ns: Ns,
    /// Pure compute per iteration per node (no communication).
    pub compute_ns: Ns,
    /// iter_ns - compute_ns: the communication the schedule failed to hide.
    pub exposed_comm_ns: Ns,
    /// Images (samples) per second across the whole cluster.
    pub throughput_samples_per_s: f64,
    /// Total bytes each NIC pushed (mean), for volume accounting.
    pub bytes_per_node: u64,
    /// NIC-level preemption count over the whole run.
    pub preemptions: u64,
    /// Wall-clock span of each iteration index (earliest fwd(0) start of
    /// iteration i+1 minus that of iteration i, across ALL nodes). Unlike
    /// `iter_ns` this stays meaningful under elastic churn, where
    /// leavers/joiners have gaps in their per-node start sequences; the
    /// recovery bench reads the post-churn entries directly.
    pub per_iter_ns: Vec<Ns>,
    /// Fault-injection accounting for the run (all zeros when no
    /// [`crate::fabric::ChaosPlan`] was installed).
    pub chaos: crate::fabric::ChaosStats,
    /// Worst per-node compute slowdown factor the run was configured
    /// with: the chaos plan's per-node `slowdown_milli` compounded with
    /// the persistent straggler plan (1000 = every node healthy). The
    /// chaos factors used to be write-only in the report path — a
    /// straggler run was undiagnosable without a trace.
    pub straggler_max_milli: u64,
    /// Mean of the same combined per-node factor (rounded down).
    pub straggler_mean_milli: u64,
    /// Human-readable membership-change log, one line per applied
    /// leave/join, in application order.
    pub churn_log: Vec<String>,
    /// Node-0 Gantt view derived from the trace
    /// ([`Timeline::from_trace`]); empty unless
    /// [`EngineConfig::record_timeline`] (or `trace`) was set.
    pub timeline: Timeline,
    /// The full normalized span trace ([`EngineConfig::trace`] /
    /// `record_timeline`); `None` on untraced runs. Feeds the Chrome
    /// export and critical-path analysis (`docs/TRACING.md`).
    pub trace: Option<Trace>,
}

impl Report {
    /// Weak-scaling efficiency vs a 1-node reference report.
    pub fn efficiency_vs(&self, single: &Report) -> f64 {
        single.iter_ns as f64 / self.iter_ns as f64
    }
}

pub(crate) fn build_report(
    cfg: &EngineConfig,
    sim: &NetSim,
    iter_starts: &[Vec<Ns>],
    first_starts: &[Ns],
    churn_log: Vec<String>,
    timeline: Timeline,
    trace: Option<Trace>,
) -> Report {
    build_report_with(cfg, sim, iter_starts, first_starts, churn_log, timeline, trace, None)
}

/// [`build_report`] with an explicit total-bytes figure for the job.
/// The single-job engine owns every byte the fabric moved; a
/// multi-tenant driver passes this job's slice of the per-tenant
/// accounting instead ([`crate::fabric::sim::SimStats::tenant_bytes`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn build_report_with(
    cfg: &EngineConfig,
    sim: &NetSim,
    iter_starts: &[Vec<Ns>],
    first_starts: &[Ns],
    churn_log: Vec<String>,
    timeline: Timeline,
    trace: Option<Trace>,
    total_bytes: Option<u64>,
) -> Report {
    // Per node: mean delta between consecutive fwd(0) starts, skipping the
    // warmup (delta 0 -> 1). Requires iterations >= 1.
    let mut deltas = Vec::new();
    for starts in iter_starts {
        for w in starts.windows(2).skip(1) {
            deltas.push((w[1] - w[0]) as f64);
        }
        // The last iteration has no successor start; approximate with the
        // average of the others (steady state) — only matters when
        // iterations == 1, where we fall back to delta 0 -> 1.
        if starts.len() == 2 {
            deltas.push((starts[1] - starts[0]) as f64);
        }
    }
    let iter_ns = crate::util::stats::mean(&deltas).round() as Ns;
    // Cluster-wide iteration spans from the earliest fwd(0) start of each
    // iteration index; Ns::MAX marks indices no node ever started (can
    // only happen for trailing indices under pathological churn plans).
    let mut per_iter_ns = Vec::new();
    for w in first_starts.windows(2) {
        if w[0] != Ns::MAX && w[1] != Ns::MAX {
            per_iter_ns.push(w[1] - w[0]);
        }
    }
    let compute_ns = cfg.compute_ns_per_iter();
    let p = cfg.dist.world();
    // Every node contributes `batch` samples regardless of grouping.
    let global_batch = (cfg.batch * p) as f64;
    let throughput = if iter_ns > 0 { global_batch * 1e9 / iter_ns as f64 } else { 0.0 };
    // Combined per-node slowdown: chaos windows × persistent stragglers
    // (both 1000 = healthy). Surfaced so a slowed run is diagnosable
    // from the report alone.
    let combined: Vec<u64> = (0..p)
        .map(|i| {
            let c = cfg
                .chaos
                .as_ref()
                .and_then(|pl| pl.slowdown_milli.get(i).copied())
                .unwrap_or(1000);
            let s = cfg
                .straggler
                .as_ref()
                .and_then(|pl| pl.factor_milli.get(i).copied())
                .unwrap_or(1000);
            c * s / 1000
        })
        .collect();
    Report {
        iter_ns: iter_ns.max(1),
        compute_ns,
        exposed_comm_ns: iter_ns.saturating_sub(compute_ns),
        throughput_samples_per_s: throughput,
        bytes_per_node: total_bytes.unwrap_or(sim.stats.bytes_sent) / p as u64,
        preemptions: sim.stats.preemptions,
        per_iter_ns,
        chaos: sim.chaos_stats,
        straggler_max_milli: combined.iter().copied().max().unwrap_or(1000),
        straggler_mean_milli: if combined.is_empty() {
            1000
        } else {
            combined.iter().sum::<u64>() / combined.len() as u64
        },
        churn_log,
        timeline,
        trace,
    }
}

//! bfloat16 <-> f32 conversion (round-to-nearest-even), bit-compatible
//! with JAX/XLA's bf16.

/// Convert f32 → bf16 bits with round-to-nearest-even (ties to even).
#[inline]
pub fn f32_to_bf16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    if v.is_nan() {
        // Quiet NaN, preserving sign.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = 0x0000_8000u32;
    let lower = bits & 0x0000_FFFF;
    let upper = bits >> 16;
    // Round to nearest, ties to even on the kept LSB.
    let rounded = if lower > round_bit || (lower == round_bit && (upper & 1) == 1) {
        upper + 1
    } else {
        upper
    };
    rounded as u16
}

/// Convert bf16 bits → f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Round-trip an f32 through bf16 (the wire precision loss).
#[inline]
pub fn bf16_roundtrip(v: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(v))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 256.0, -65536.0] {
            assert_eq!(bf16_roundtrip(v), v, "{v}");
        }
    }

    #[test]
    fn relative_error_bounded() {
        // bf16 keeps 8 significand bits: rel err <= 2^-8.
        let mut x = 0.001f32;
        while x < 1e6 {
            let r = bf16_roundtrip(x);
            assert!((r - x).abs() <= x / 256.0, "{x} -> {r}");
            x *= 1.37;
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1.0 + 2^-9 is exactly halfway between 1.0 and 1.00390625 (the
        // next bf16); ties-to-even keeps 1.0 (even LSB).
        let halfway = f32::from_bits(0x3F80_8000);
        assert_eq!(bf16_roundtrip(halfway), 1.0);
        // Just above halfway rounds up.
        let above = f32::from_bits(0x3F80_8001);
        assert_eq!(bf16_roundtrip(above), bf16_bits_to_f32(0x3F81));
    }

    #[test]
    fn nan_and_inf() {
        assert!(bf16_roundtrip(f32::NAN).is_nan());
        assert_eq!(bf16_roundtrip(f32::INFINITY), f32::INFINITY);
        assert_eq!(bf16_roundtrip(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }
}

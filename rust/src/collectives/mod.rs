//! Collectives: algorithms, wire formats, priorities, selection.
//!
//! A collective is compiled into one *chunk program per rank*
//! ([`program`]): an ordered list of steps, each an optional send and an
//! optional receive(+reduce) over an element range. The same programs are
//! executed two ways:
//!
//! * **really** — [`exec`] moves actual bytes over the in-process
//!   [`crate::fabric::shm`] fabric (the training path), with low-precision
//!   wire formats from [`quant`];
//! * **symbolically** — [`verify`] checks algebraic correctness (every
//!   rank ends with every rank's contribution exactly once), which is the
//!   proptest invariant; and the [`crate::engine`] *times* them against
//!   the discrete-event fabric.
//!
//! Algorithm choice ([`selector`]) follows the paper's "implements
//! performance critical data path operations in an optimal manner":
//! latency-optimal recursive doubling for small payloads,
//! bandwidth-optimal ring for large ones, halving-doubling in between.

pub mod exec;
pub mod priority;
pub mod program;
pub mod quant;
pub mod selector;
pub mod simexec;
pub mod verify;

pub use priority::PriorityPolicy;
pub use program::{CollectiveKind, Program, Range, RecvStep, SendStep, Step};
pub use quant::WireDtype;
pub use selector::choose_algorithm;

/// Reduction operator applied element-wise during reducing receives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceOp {
    Sum,
    Max,
    Min,
}

impl ReduceOp {
    #[inline]
    pub fn apply(&self, a: f32, b: f32) -> f32 {
        match self {
            ReduceOp::Sum => a + b,
            ReduceOp::Max => a.max(b),
            ReduceOp::Min => a.min(b),
        }
    }
}

/// Collective algorithm family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Pipeline ring: bandwidth-optimal, 2(P−1) steps of n/P elements.
    Ring,
    /// Recursive doubling on the full buffer: log₂P steps of n elements —
    /// latency-optimal for small messages. P must be a power of two.
    RecursiveDoubling,
    /// Rabenseifner reduce-scatter-halving + allgather-doubling:
    /// bandwidth-optimal with log₂P steps. P must be a power of two.
    HalvingDoubling,
    /// Let the library pick per message size / rank count (the default).
    Auto,
}

impl std::fmt::Display for Algorithm {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Algorithm::Ring => "ring",
            Algorithm::RecursiveDoubling => "rdoubling",
            Algorithm::HalvingDoubling => "halving",
            Algorithm::Auto => "auto",
        };
        f.write_str(s)
    }
}

//! Deterministic xoshiro256** PRNG (offline replacement for `rand`).
//! Used by the synthetic corpus generator, parameter init fallbacks and
//! the property-test harness. Seeded → fully reproducible runs.

#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn seed(seed: u64) -> Self {
        // SplitMix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift rejection-free approximation is fine for
        // our non-cryptographic uses.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Zipf-distributed value in [0, n) with exponent `s` (token sampling).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        // Inverse-CDF on a truncated harmonic approximation.
        let u = self.f64();
        let hmax = ((n as f64).powf(1.0 - s) - 1.0) / (1.0 - s) + 1.0;
        let x = ((u * hmax * (1.0 - s) - (1.0 - s) + 1.0).powf(1.0 / (1.0 - s))).max(1.0);
        (x as usize - 1).min(n - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Prng::seed(42);
        let mut b = Prng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Prng::seed(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Prng::seed(1);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Prng::seed(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Prng::seed(3);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "{mean}");
        assert!((var - 1.0).abs() < 0.1, "{var}");
    }

    #[test]
    fn zipf_is_skewed_to_small_values() {
        let mut r = Prng::seed(9);
        let mut lo = 0;
        for _ in 0..5_000 {
            if r.zipf(1000, 1.2) < 10 {
                lo += 1;
            }
        }
        // With s=1.2 the first 10 of 1000 values carry a large mass.
        assert!(lo > 1_000, "only {lo}/5000 in the head");
    }
}

//! The asynchronous progress engine — MLSL's "dedicating one or more
//! cores for driving the network".
//!
//! Each rank spawns a [`CommCore`]: a dedicated thread owning the rank's
//! fabric endpoint. The main (compute) thread submits non-blocking
//! collective operations and gets a [`Handle`]; the comm core interleaves
//! the chunk programs of ALL in-flight operations, always advancing the
//! highest-priority one that can make progress — step-granular
//! **preemption**: an urgent first-layer gradient allreduce submitted
//! while a bulk later-layer exchange is in flight overtakes it on the
//! wire, exactly the paper's message-prioritization mechanism.

pub mod engine;
pub mod handle;

pub use engine::{CommCore, OpSubmit};
pub use handle::Handle;

//! Fabric + node parameter presets for the paper's testbeds — now a
//! **two-tier** model.
//!
//! Real clusters run more than one rank per node: a fast intra-node tier
//! (shared memory / QPI) connects co-located ranks, a much slower
//! inter-node tier (Omni-Path / Ethernet NICs) connects nodes. A
//! [`Topology`] therefore carries parameters for BOTH tiers plus
//! `ranks_per_node`; ranks are grouped contiguously (`node = rank /
//! ranks_per_node`), and every point-to-point cost helper comes in a
//! `*_between(src, dst, ..)` form that prices the hop at its tier.
//! `ranks_per_node == 1` collapses to the old flat single-tier model and
//! every legacy helper (`wire_ns`, `msg_ns`) keeps pricing the inter tier.
//!
//! Numbers are public-spec-derived, not measured on the authors' clusters;
//! EXPERIMENTS.md compares *shapes* (who wins, by what factor), which these
//! presets preserve (10GbE: high latency + low bandwidth → prioritization
//! matters most; Omnipath: low latency + high bandwidth → near-ideal
//! scaling with overlap; `-x<r>` smp variants: hierarchical collectives
//! win once the intra tier can absorb the first reduction level).

use crate::{Ns, Rank};

/// Which tier a (src, dst) rank pair communicates over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Co-located ranks (same node): shared-memory-class links.
    Intra,
    /// Ranks on different nodes: the cluster fabric.
    Inter,
}

/// Shared-memory tier defaults (Skylake-class socket pair): ~75 GB/s
/// effective copy bandwidth, sub-µs latency, cheap doorbells.
const INTRA_GBPS: f64 = 600.0;
const INTRA_LATENCY_NS: Ns = 700;
const INTRA_OVERHEAD_NS: Ns = 150;

/// Network fabric parameters (a two-tier alpha–beta–gamma model).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    pub name: String,
    /// Per-NIC egress line rate, Gbit/s (inter-node beta⁻¹).
    pub link_gbps: f64,
    /// End-to-end message latency, ns (inter-node alpha): propagation +
    /// switching.
    pub latency_ns: Ns,
    /// Per-message software/NIC injection overhead, ns (gamma). Paid on
    /// the egress wire before the first byte moves — this is what makes
    /// small messages latency-bound and motivates prioritization.
    pub per_msg_overhead_ns: Ns,
    /// Chunk size collectives use on this fabric, bytes. Preemption is
    /// chunk-granular, so this is also the preemption latency knob.
    pub chunk_bytes: u64,
    /// Ranks co-located on one node (contiguous grouping). 1 = flat
    /// single-tier fabric (the legacy model).
    pub ranks_per_node: usize,
    /// Intra-node tier line rate, Gbit/s (shared-memory class).
    pub intra_gbps: f64,
    /// Intra-node tier message latency, ns.
    pub intra_latency_ns: Ns,
    /// Intra-node per-message overhead, ns.
    pub intra_per_msg_overhead_ns: Ns,
}

impl Topology {
    /// 10 Gbit/s Ethernet, TCP-class latency — the fabric of the paper's
    /// 1.8–2.2× prioritization result (C1).
    pub fn eth_10g() -> Self {
        Self {
            name: "eth10g".into(),
            link_gbps: 10.0,
            latency_ns: 30_000,          // ~30 µs TCP/Ethernet stack
            per_msg_overhead_ns: 4_000,  // kernel/NIC doorbell path
            chunk_bytes: 256 * 1024,
            ranks_per_node: 1,
            intra_gbps: INTRA_GBPS,
            intra_latency_ns: INTRA_LATENCY_NS,
            intra_per_msg_overhead_ns: INTRA_OVERHEAD_NS,
        }
    }

    /// Intel Omnipath-class 100 Gbit/s HPC fabric — Fig. 2's testbed.
    pub fn omnipath_100g() -> Self {
        Self {
            name: "omnipath100g".into(),
            link_gbps: 100.0,
            latency_ns: 1_100,          // ~1.1 µs MPI pingpong
            per_msg_overhead_ns: 250,
            chunk_bytes: 1024 * 1024,
            ranks_per_node: 1,
            intra_gbps: INTRA_GBPS,
            intra_latency_ns: INTRA_LATENCY_NS,
            intra_per_msg_overhead_ns: INTRA_OVERHEAD_NS,
        }
    }

    /// 25 GbE cloud fabric (intermediate point, used in ablations).
    pub fn eth_25g() -> Self {
        Self {
            name: "eth25g".into(),
            link_gbps: 25.0,
            latency_ns: 15_000,
            per_msg_overhead_ns: 2_000,
            chunk_bytes: 512 * 1024,
            ranks_per_node: 1,
            intra_gbps: INTRA_GBPS,
            intra_latency_ns: INTRA_LATENCY_NS,
            intra_per_msg_overhead_ns: INTRA_OVERHEAD_NS,
        }
    }

    /// Multi-rank-per-node variant of any preset: `r` ranks share each
    /// node's NIC-facing tier and talk shared-memory within the node. The
    /// name gains an `-x<r>` suffix (so presets resolve round-trip through
    /// [`Topology::by_name`]).
    pub fn with_ranks_per_node(mut self, r: usize) -> Self {
        assert!(r >= 1, "ranks_per_node must be >= 1");
        let base = match self.name.rsplit_once("-x") {
            Some((b, suffix)) if suffix.parse::<usize>().is_ok() => b.to_string(),
            _ => self.name.clone(),
        };
        self.name = if r == 1 { base } else { format!("{base}-x{r}") };
        self.ranks_per_node = r;
        self
    }

    /// The paper's Xeon/10GbE testbed at >1 rank per node.
    pub fn eth_10g_smp(ranks_per_node: usize) -> Self {
        Self::eth_10g().with_ranks_per_node(ranks_per_node)
    }

    /// The paper's Xeon/Omni-Path testbed at >1 rank per node.
    pub fn omnipath_100g_smp(ranks_per_node: usize) -> Self {
        Self::omnipath_100g().with_ranks_per_node(ranks_per_node)
    }

    /// Resolve a preset name; `-x<r>` suffixes select the smp variant
    /// (e.g. `eth10g-x2`, `opa-x4`).
    pub fn by_name(name: &str) -> Option<Self> {
        if let Some((base, suffix)) = name.rsplit_once("-x") {
            if let Ok(r) = suffix.parse::<usize>() {
                if r >= 1 {
                    return Self::by_name(base).map(|t| t.with_ranks_per_node(r));
                }
            }
        }
        match name {
            "eth10g" => Some(Self::eth_10g()),
            "eth25g" => Some(Self::eth_25g()),
            "omnipath100g" | "opa" => Some(Self::omnipath_100g()),
            _ => None,
        }
    }

    // -- tier resolution ----------------------------------------------------

    /// Node index of `rank` under contiguous grouping.
    pub fn node_of(&self, rank: Rank) -> usize {
        rank / self.ranks_per_node.max(1)
    }

    /// Do `a` and `b` share a node? (Never true on flat topologies.)
    pub fn same_node(&self, a: Rank, b: Rank) -> bool {
        self.ranks_per_node > 1 && self.node_of(a) == self.node_of(b)
    }

    /// Tier of the (src, dst) hop.
    pub fn tier(&self, src: Rank, dst: Rank) -> Tier {
        if self.same_node(src, dst) { Tier::Intra } else { Tier::Inter }
    }

    /// Does this fabric have a meaningful intra-node tier?
    pub fn is_hierarchical(&self) -> bool {
        self.ranks_per_node > 1
    }

    /// True when `members` decompose into whole nodes: consecutive runs of
    /// `ranks_per_node` ranks, each starting at a node boundary.
    /// Hierarchical collectives are only valid over such sets.
    pub fn ranks_node_aligned(&self, members: &[Rank]) -> bool {
        let rpn = self.ranks_per_node;
        rpn > 1
            && !members.is_empty()
            && members.len() % rpn == 0
            && members.chunks(rpn).all(|c| {
                c[0] % rpn == 0 && c.windows(2).all(|w| w[1] == w[0] + 1)
            })
    }

    /// Line rate of a tier, Gbit/s.
    pub fn gbps_of(&self, tier: Tier) -> f64 {
        match tier {
            Tier::Intra => self.intra_gbps,
            Tier::Inter => self.link_gbps,
        }
    }

    /// Message latency of a tier, ns.
    pub fn latency_of(&self, tier: Tier) -> Ns {
        match tier {
            Tier::Intra => self.intra_latency_ns,
            Tier::Inter => self.latency_ns,
        }
    }

    /// Per-message overhead of a tier, ns.
    pub fn overhead_of(&self, tier: Tier) -> Ns {
        match tier {
            Tier::Intra => self.intra_per_msg_overhead_ns,
            Tier::Inter => self.per_msg_overhead_ns,
        }
    }

    // -- hop costs ------------------------------------------------------------

    /// Pure wire time for `bytes` on the INTER tier (no latency/overhead).
    /// Legacy helper: flat topologies have only this tier.
    pub fn wire_ns(&self, bytes: u64) -> Ns {
        super::wire_ns(bytes, self.link_gbps)
    }

    /// Full cost of a single INTER-tier point-to-point message.
    pub fn msg_ns(&self, bytes: u64) -> Ns {
        self.per_msg_overhead_ns + self.wire_ns(bytes) + self.latency_ns
    }

    /// Full cost of a single INTRA-tier point-to-point message.
    pub fn intra_msg_ns(&self, bytes: u64) -> Ns {
        self.intra_per_msg_overhead_ns
            + super::wire_ns(bytes, self.intra_gbps)
            + self.intra_latency_ns
    }

    /// Wire time of `bytes` between two concrete ranks (tier-priced).
    pub fn wire_ns_between(&self, src: Rank, dst: Rank, bytes: u64) -> Ns {
        super::wire_ns(bytes, self.gbps_of(self.tier(src, dst)))
    }

    /// Per-message overhead between two concrete ranks.
    pub fn overhead_between(&self, src: Rank, dst: Rank) -> Ns {
        self.overhead_of(self.tier(src, dst))
    }

    /// In-flight latency between two concrete ranks.
    pub fn latency_between(&self, src: Rank, dst: Rank) -> Ns {
        self.latency_of(self.tier(src, dst))
    }

    /// Full cost of a message between two concrete ranks.
    pub fn msg_ns_between(&self, src: Rank, dst: Rank, bytes: u64) -> Ns {
        self.overhead_between(src, dst)
            + self.wire_ns_between(src, dst, bytes)
            + self.latency_between(src, dst)
    }
}

/// Node compute model (Skylake-class by default).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    /// Peak single-precision FLOP/s of the whole socket pair.
    pub peak_flops: f64,
    /// Fraction of peak a tuned DL framework sustains (conv/gemm mix).
    pub dl_efficiency: f64,
    /// Physical cores (comm cores are stolen from these).
    pub cores: usize,
}

impl NodeSpec {
    /// 2× Intel Xeon Gold 6148 (Skylake, the paper's node): 2 × 20 cores ×
    /// 2 AVX-512 FMA units × 16 f32 lanes × 2 flop × 2.4 GHz ≈ 6.1 Tf/s.
    pub fn skylake_6148() -> Self {
        Self {
            name: "2xXeon6148".into(),
            peak_flops: 6.1e12,
            dl_efficiency: 0.55,
            cores: 40,
        }
    }

    /// Xeon Phi 7250 (the 9600-node Cori run cited by the paper).
    pub fn xeon_phi_7250() -> Self {
        Self {
            name: "XeonPhi7250".into(),
            peak_flops: 6.0e12,
            dl_efficiency: 0.35,
            cores: 68,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "skylake" | "2xXeon6148" => Some(Self::skylake_6148()),
            "knl" | "XeonPhi7250" => Some(Self::xeon_phi_7250()),
            _ => None,
        }
    }

    /// Sustained FLOP/s with `comm_cores` dedicated to driving the network
    /// (the paper: "dedicating one or more cores for driving the network").
    pub fn effective_flops(&self, comm_cores: usize) -> f64 {
        let compute_cores = self.cores.saturating_sub(comm_cores).max(1);
        self.peak_flops * self.dl_efficiency * compute_cores as f64 / self.cores as f64
    }

    /// Time to execute `flops` floating point ops, ns.
    pub fn compute_ns(&self, flops: f64, comm_cores: usize) -> Ns {
        (flops / self.effective_flops(comm_cores) * 1e9).ceil() as Ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_time_scales_linearly() {
        let t = Topology::eth_10g();
        // 10 Gbps = 1.25 B/ns -> 1 MiB takes 1048576/1.25 ≈ 838861 ns.
        assert_eq!(t.wire_ns(1_048_576), 838_861);
        assert!(t.wire_ns(2 * 1_048_576) >= 2 * t.wire_ns(1_048_576) - 1);
    }

    #[test]
    fn omnipath_beats_ethernet() {
        let e = Topology::eth_10g();
        let o = Topology::omnipath_100g();
        assert!(o.msg_ns(1024) < e.msg_ns(1024));
        assert!(o.msg_ns(16 << 20) < e.msg_ns(16 << 20));
    }

    #[test]
    fn comm_cores_reduce_compute_rate() {
        let n = NodeSpec::skylake_6148();
        assert!(n.effective_flops(2) < n.effective_flops(0));
        // Stealing 2 of 40 cores costs 5%.
        let ratio = n.effective_flops(2) / n.effective_flops(0);
        assert!((ratio - 38.0 / 40.0).abs() < 1e-9);
    }

    #[test]
    fn presets_resolve_by_name() {
        assert!(Topology::by_name("eth10g").is_some());
        assert!(Topology::by_name("opa").is_some());
        assert!(Topology::by_name("nope").is_none());
        assert!(NodeSpec::by_name("skylake").is_some());
    }

    #[test]
    fn smp_presets_resolve_and_roundtrip() {
        let t = Topology::by_name("eth10g-x4").unwrap();
        assert_eq!(t.ranks_per_node, 4);
        assert_eq!(t.name, "eth10g-x4");
        assert_eq!(Topology::by_name(&t.name).unwrap(), t);
        let o = Topology::omnipath_100g_smp(2);
        assert_eq!(o.name, "omnipath100g-x2");
        assert_eq!(Topology::by_name("opa-x2").unwrap().ranks_per_node, 2);
        assert!(Topology::by_name("nope-x2").is_none());
        // Re-suffixing replaces, never stacks.
        let again = t.with_ranks_per_node(2);
        assert_eq!(again.name, "eth10g-x2");
        assert_eq!(again.with_ranks_per_node(1).name, "eth10g");
    }

    #[test]
    fn tiers_resolve_by_node_grouping() {
        let t = Topology::eth_10g_smp(4);
        assert!(t.is_hierarchical());
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert!(t.same_node(1, 2));
        assert!(!t.same_node(3, 4));
        assert_eq!(t.tier(0, 1), Tier::Intra);
        assert_eq!(t.tier(0, 4), Tier::Inter);
        // Flat fabrics never resolve to the intra tier.
        let flat = Topology::eth_10g();
        assert!(!flat.same_node(0, 0));
        assert_eq!(flat.tier(0, 1), Tier::Inter);
    }

    #[test]
    fn intra_hops_are_much_cheaper() {
        let t = Topology::eth_10g_smp(2);
        let b = 1 << 20;
        assert!(t.msg_ns_between(0, 1, b) < t.msg_ns_between(1, 2, b) / 10);
        // Inter-tier helpers agree with the legacy flat helpers.
        assert_eq!(t.msg_ns_between(1, 2, b), t.msg_ns(b));
        assert_eq!(t.msg_ns_between(0, 1, b), t.intra_msg_ns(b));
    }

    #[test]
    fn node_alignment_detection() {
        let t = Topology::eth_10g_smp(2);
        assert!(t.ranks_node_aligned(&[0, 1, 2, 3]));
        assert!(t.ranks_node_aligned(&[4, 5]));
        assert!(!t.ranks_node_aligned(&[1, 2])); // straddles nodes
        assert!(!t.ranks_node_aligned(&[0, 2, 4, 6])); // strided
        assert!(!t.ranks_node_aligned(&[0, 1, 2])); // partial node
        assert!(!t.ranks_node_aligned(&[]));
        assert!(!Topology::eth_10g().ranks_node_aligned(&[0, 1])); // flat
    }
}

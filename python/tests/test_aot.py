"""AOT pipeline tests: lowering produces loadable HLO text + sane manifest."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, kernels, model
from compile.presets import PRESETS


def test_to_hlo_text_roundtrips_numerics(tmp_path):
    """Lowered HLO text, recompiled through xla_client, matches jax output."""
    from jax._src.lib import xla_client as xc

    def fn(x, y):
        return (kernels.matmul_bias_act(x, y, jnp.zeros((4,), jnp.float32)),)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "ENTRY" in text  # HLO text, not a proto
    # Ids must be text-parseable (the 64-bit-id pitfall shows up as parse fail).
    assert len(text) > 100


def test_emit_tiny_preset(tmp_path):
    out = str(tmp_path)
    aot.emit_preset("tiny", out, lr=0.05, mu=0.9, wd=0.0)
    pdir = os.path.join(out, "tiny")
    manifest = json.load(open(os.path.join(pdir, "manifest.json")))
    specs = model.param_specs(PRESETS["tiny"])
    assert manifest["model"]["n_param_tensors"] == len(specs)
    assert manifest["hparams"]["lr"] == 0.05
    for art in ["grad_step", "apply_update", "train_step", "eval_loss"]:
        entry = manifest["artifacts"][art]
        path = os.path.join(pdir, entry["file"])
        assert os.path.exists(path), path
        head = open(path).read(4096)
        assert "HloModule" in head
    # IO orderings: grad_step outputs = loss + one grad per param, in order.
    gs = manifest["artifacts"]["grad_step"]
    assert gs["outputs"][0] == "loss"
    assert gs["outputs"][1:] == [f"grad.{s['name']}" for s in specs]
    au = manifest["artifacts"]["apply_update"]
    assert len(au["inputs"]) == 3 * len(specs)
    assert len(au["outputs"]) == 2 * len(specs)


def test_emit_micro(tmp_path):
    out = str(tmp_path)
    aot.emit_micro(out)
    manifest = json.load(open(os.path.join(out, "micro", "manifest.json")))
    assert manifest["quant_roundtrip"]["qblock"] == kernels.QBLOCK
    for k in ("quant_roundtrip", "matmul"):
        assert os.path.exists(os.path.join(out, "micro", manifest[k]["file"]))


def test_cli_runs(tmp_path):
    """aot.py is the `make artifacts` entry point; exercise the CLI."""
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--presets", "tiny", "--skip-heavy"],
        cwd=repo_py, env=env, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr
    assert os.path.exists(os.path.join(str(tmp_path), ".stamp"))
    manifest = json.load(open(os.path.join(str(tmp_path), "tiny", "manifest.json")))
    assert manifest["artifacts"]["train_step"] is None  # --skip-heavy

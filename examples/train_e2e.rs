//! END-TO-END validation: train a real Transformer LM through the full
//! three-layer stack —
//!
//!   Rust ranks → PJRT executables (AOT-lowered JAX, whose hot spots are
//!   Pallas kernels) → gradients allreduced by THIS library's prioritized
//!   comm cores → fused-SGD update executable.
//!
//! Python is not involved: `make artifacts` must have been run once.
//!
//! Defaults train the `small` preset (~6M params) for 200 steps on 2
//! ranks and print the loss curve; EXPERIMENTS.md §E2E records a run.
//!
//! Run: `cargo run --release --example train_e2e -- [--steps 200]
//!       [--ranks 2] [--preset small] [--wire f32|bf16|int8]`

use mlsl::collectives::{PriorityPolicy, WireDtype};
use mlsl::trainer::{train, TrainerConfig};
use mlsl::util::cli::Args;
use mlsl::util::stats::mean;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let preset = args.str_or("preset", "small");
    let artifacts = args.str_or("artifacts", &format!("artifacts/{preset}"));
    let mut cfg = TrainerConfig::new(&artifacts);
    cfg.ranks = args.usize_or("ranks", 2);
    cfg.steps = args.usize_or("steps", 200);
    cfg.log_every = args.usize_or("log-every", 10);
    cfg.wire = WireDtype::by_name(&args.str_or("wire", "f32")).expect("--wire");
    cfg.policy = PriorityPolicy::by_name(&args.str_or("policy", "bylayer")).expect("--policy");
    cfg.seed = args.usize_or("seed", 42) as u64;

    eprintln!(
        "train_e2e: preset={preset} ranks={} steps={} wire={} (artifacts: {artifacts})",
        cfg.ranks, cfg.steps, cfg.wire
    );
    let t0 = std::time::Instant::now();
    let res = train(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    // Loss curve, decimated to ~25 lines.
    println!("\nstep,loss");
    let stride = (res.losses.len() / 25).max(1);
    for (i, l) in res.losses.iter().enumerate() {
        if i % stride == 0 || i + 1 == res.losses.len() {
            println!("{i},{l:.4}");
        }
    }

    let first = res.losses[0];
    let last = *res.losses.last().unwrap();
    println!("\n== train_e2e summary ==");
    println!("params tensors     : {}", res.n_params);
    println!("loss               : {first:.4} -> {last:.4}");
    println!("wall               : {wall:.1} s total, {:.1} ms/step", mean(&res.step_ms));
    println!("comm wait          : {:.2} ms/step", mean(&res.comm_wait_ms));
    anyhow::ensure!(last < first, "loss did not decrease");
    println!("OK: all three layers compose; loss decreases through the real stack");

    if let Some(out) = args.get("loss-csv") {
        let rows: Vec<Vec<String>> = res
            .losses
            .iter()
            .enumerate()
            .map(|(i, l)| vec![i.to_string(), l.to_string(), format!("{:.2}", res.step_ms[i])])
            .collect();
        mlsl::metrics::write_csv(std::path::Path::new(out), &["step", "loss", "ms"], &rows)?;
        eprintln!("wrote {out}");
    }
    Ok(())
}

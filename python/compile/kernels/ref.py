"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness signal: pytest (and hypothesis sweeps)
assert that each Pallas kernel (run with interpret=True) matches its
oracle to tight tolerances across shapes and dtypes.

Nothing in here is performance-relevant; clarity over speed.
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Matmul + bias + activation (the MLP hot path)
# ---------------------------------------------------------------------------


def matmul_bias_act(x, w, b, activation: str = "none"):
    """out = act(x @ w + b).

    x: (M, K) float32/bfloat16
    w: (K, N)
    b: (N,)
    activation: "none" | "gelu" | "relu"
    """
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    out = out + b.astype(jnp.float32)
    if activation == "gelu":
        out = jax.nn.gelu(out, approximate=True)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation!r}")
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Fused scaled-dot-product attention (per batch*head slice)
# ---------------------------------------------------------------------------


def attention(q, k, v, causal: bool = True):
    """softmax(q k^T / sqrt(d) [+ causal mask]) v.

    q, k, v: (B, H, S, D) — batch, heads, sequence, head_dim.
    """
    b, h, s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    logits = jnp.einsum(
        "bhsd,bhtd->bhst", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Gradient quantization (per-block absmax int8), the paper's low-precision
# communication path ("Reducing communication volume")
# ---------------------------------------------------------------------------

QBLOCK = 256  # elements per quantization block (one scale per block)


def quantize_int8(x):
    """Per-block absmax int8 quantization.

    x: (n,) float32 with n % QBLOCK == 0.
    Returns (q:int8 (n,), scales:float32 (n/QBLOCK,)).
    """
    blocks = x.reshape(-1, QBLOCK)
    absmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_int8(q, scale):
    """Inverse of quantize_int8 (lossy)."""
    blocks = q.reshape(-1, QBLOCK).astype(jnp.float32)
    return (blocks * scale[:, None]).reshape(-1)


# ---------------------------------------------------------------------------
# Fused SGD with momentum (the weight-update the paper's first-layer
# prioritization exists to unblock)
# ---------------------------------------------------------------------------


def sgd_momentum(w, m, g, lr: float, mu: float, weight_decay: float = 0.0):
    """m' = mu*m + g + wd*w ;  w' = w - lr*m'. Returns (w', m')."""
    g = g + weight_decay * w
    m_new = mu * m + g
    w_new = w - lr * m_new
    return w_new, m_new


# ---------------------------------------------------------------------------
# LayerNorm (used by the model; kernelized as fused normalize+affine)
# ---------------------------------------------------------------------------


def layernorm(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis. x: (..., D)."""
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mean), axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(x.dtype)

//! Property tests for the partitioned parallel simulator
//! (`collectives::parexec`): partitioning is an *implementation detail*
//! of the clock, never of the physics.
//!
//! For random topologies, collective builders, sizes and chaos plans,
//! a partitioned run at any (shards, threads) must reproduce the serial
//! simulator **byte-identically**:
//!
//! * the delivered-message multiset (every src/dst/bytes/priority/tag,
//!   with its delivery timestamp);
//! * per-rank completion timestamps and the finish time;
//! * the final fabric clock after full drain (trailing chaos windows
//!   included);
//! * traffic stats and every chaos fault counter.
//!
//! See `docs/ARCHITECTURE.md` §"Partitioned mode" for why conservative
//! lookahead makes this exact rather than approximate.

use mlsl::collectives::parexec::{
    run_collective, run_collective_serial, run_pattern, FleetConfig, PatternSpec,
};
use mlsl::collectives::program::{build, CollectiveKind};
use mlsl::collectives::{Algorithm as A, WireDtype};
use mlsl::fabric::topology::Topology;
use mlsl::fabric::ChaosPlan;
use mlsl::util::proptest::{run as prop_run, Config};

/// Random test fabric: flat, smp, multi-rail or racked — the partition
/// boundary must be safe on all of them.
fn random_topo(pick: usize) -> Topology {
    match pick % 4 {
        0 => Topology::flat("partest", 8.0, 1_000, 100, 1 << 20),
        1 => Topology::by_name("eth10g-x2").unwrap(),
        2 => Topology::by_name("eth10g-x2e2").unwrap(),
        _ => Topology::by_name("eth10g-x2r4").unwrap(),
    }
}

#[test]
fn prop_partitioned_collectives_match_serial_byte_for_byte() {
    prop_run(
        Config { cases: 40, seed: 91 },
        |r| {
            let topo_pick = r.usize_below(4);
            let p = 2 + r.usize_below(63); // 2..65
            let n = 1 + r.usize_below(2_000);
            let alg = if p.is_power_of_two() && r.below(2) == 0 {
                A::RecursiveDoubling
            } else {
                A::Ring
            };
            let kind = if r.below(2) == 0 {
                CollectiveKind::Allreduce
            } else {
                CollectiveKind::Allgather
            };
            let chaos_seed = if r.below(2) == 0 { Some(r.below(u64::MAX)) } else { None };
            let shards = 2 + r.usize_below(3); // 2..=4
            let threads = [1usize, 2, 4][r.usize_below(3)];
            (topo_pick, p, n, kind, alg, chaos_seed, shards, threads)
        },
        |&(topo_pick, p, n, kind, alg, chaos_seed, shards, threads)| {
            let topo = random_topo(topo_pick);
            let progs = build(kind, alg, p, n).map_err(|e| e.to_string())?;
            let chaos = chaos_seed.map(|s| ChaosPlan::generate(s, &topo, p, 2_000_000));
            let label = format!(
                "{kind:?}/{alg} p={p} n={n} topo={} chaos={chaos_seed:?} \
                 shards={shards} threads={threads}",
                topo.name
            );
            let serial = run_collective_serial(
                &topo,
                p,
                progs.clone(),
                WireDtype::F32,
                1,
                chaos.as_ref(),
                true,
                false,
            );
            let cfg = FleetConfig { shards, threads, chaos, record_deliveries: true, trace: false };
            let par = run_collective(&topo, p, progs.clone(), WireDtype::F32, 1, &cfg);
            if par.delivered != serial.delivered {
                return Err(format!("{label}: delivered-message multisets diverged"));
            }
            if par.completions != serial.completions {
                return Err(format!("{label}: completion timestamps diverged"));
            }
            if par.finish_ns != serial.finish_ns || par.final_clock != serial.final_clock {
                return Err(format!(
                    "{label}: finish {} vs {} / final clock {} vs {}",
                    par.finish_ns, serial.finish_ns, par.final_clock, serial.final_clock
                ));
            }
            if par.stats.msgs_sent != serial.stats.msgs_sent
                || par.stats.bytes_sent != serial.stats.bytes_sent
                || par.stats.bytes_by_priority != serial.stats.bytes_by_priority
            {
                return Err(format!("{label}: traffic stats diverged"));
            }
            if par.chaos != serial.chaos {
                return Err(format!(
                    "{label}: chaos counters diverged ({:?} vs {:?})",
                    par.chaos, serial.chaos
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_pattern_runs_are_partition_invariant() {
    // The O(p)-state pattern drivers (the datacenter-scale bench path)
    // obey the same invariant: finish time, message count and moved
    // bytes are independent of the partitioning.
    prop_run(
        Config { cases: 40, seed: 92 },
        |r| {
            let topo_pick = r.usize_below(4);
            let pow2 = r.below(2) == 0;
            let p = if pow2 {
                1usize << (2 + r.usize_below(5)) // 4..=64
            } else {
                3 + r.usize_below(62) // 3..65
            };
            let bytes = 1 + r.below(64 << 10);
            let shards = 2 + r.usize_below(3);
            let threads = [1usize, 2, 4][r.usize_below(3)];
            (topo_pick, pow2, p, bytes, shards, threads)
        },
        |&(topo_pick, pow2, p, bytes, shards, threads)| {
            let topo = random_topo(topo_pick);
            let spec = if pow2 {
                PatternSpec::rdoubling_allreduce(p, bytes)
            } else {
                PatternSpec::ring_allreduce(p, bytes)
            };
            let label = format!(
                "{:?} p={p} bytes={bytes} topo={} shards={shards} threads={threads}",
                spec.pattern, topo.name
            );
            let serial = run_pattern(
                &topo,
                &spec,
                &FleetConfig {
                    shards: 1,
                    threads: 1,
                    chaos: None,
                    record_deliveries: false,
                    trace: false,
                },
            );
            let par = run_pattern(
                &topo,
                &spec,
                &FleetConfig {
                    shards,
                    threads,
                    chaos: None,
                    record_deliveries: false,
                    trace: false,
                },
            );
            if par.finish_ns != serial.finish_ns || par.final_clock != serial.final_clock {
                return Err(format!(
                    "{label}: finish {} vs {} / clock {} vs {}",
                    par.finish_ns, serial.finish_ns, par.final_clock, serial.final_clock
                ));
            }
            if par.stats.msgs_sent != serial.stats.msgs_sent
                || par.stats.msgs_sent != spec.total_msgs()
                || par.stats.bytes_sent != serial.stats.bytes_sent
            {
                return Err(format!("{label}: traffic stats diverged"));
            }
            Ok(())
        },
    );
}

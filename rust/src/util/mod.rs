//! In-tree utility substrates.
//!
//! This image builds offline; small third-party conveniences are therefore
//! implemented here: [`bf16`] conversion (would be the `half` crate),
//! [`json`] parsing/serialization (would be `serde_json` — needed for the
//! AOT manifests), [`cli`] flag parsing (would be `clap`), [`prng`] a
//! deterministic xorshift generator (would be `rand`), and [`proptest`] a
//! minimal property-testing harness used by the randomized invariant tests.
//! [`warn`] is the single stderr funnel for user-facing diagnostics (the
//! warning contract is documented in `docs/ARCHITECTURE.md`).

pub mod bf16;
pub mod cli;
pub mod json;
pub mod prng;
pub mod proptest;
pub mod stats;
pub mod warn;

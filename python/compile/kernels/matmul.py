"""Tiled matmul + bias + activation Pallas kernel (the MLP/projection hot path).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid is (M/bm, N/bn, K/bk)
with an f32 accumulator living in the output block across the K steps — the
classic MXU-feeding schedule. BlockSpecs express the HBM->VMEM movement the
paper's Xeon implementation did with cache blocking. On this image the kernel
runs under interpret=True (CPU PJRT cannot execute Mosaic custom-calls); the
*structure* (128-multiple tiles, f32 accumulation, K-innermost) is what the
MXU-utilization estimate in EXPERIMENTS.md §Perf is based on.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes: multiples of the MXU systolic array (128x128) and the
# (8, 128) f32 VMEM tiling. Shrunk automatically for small test shapes.
DEF_BM = 128
DEF_BN = 128
DEF_BK = 128


def _pick(block: int, dim: int) -> int:
    """Largest divisor of `dim` that is <= block (keeps grids exact)."""
    b = min(block, dim)
    while dim % b != 0:
        b -= 1
    return b


def _mm_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == nk - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...].astype(jnp.float32)[None, :]
        if activation == "gelu":
            acc = jax.nn.gelu(acc, approximate=True)
        elif activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("activation", "bm", "bn", "bk"))
def matmul_bias_act(x, w, b, activation: str = "none", bm=DEF_BM, bn=DEF_BN, bk=DEF_BK):
    """act(x @ w + b) as a single fused Pallas kernel.

    x: (M, K); w: (K, N); b: (N,). Returns (M, N) in x.dtype.
    Accumulation is always f32 (MXU-style), output cast back.
    """
    if activation not in ("none", "gelu", "relu"):
        raise ValueError(f"unknown activation {activation!r}")
    m, kdim = x.shape
    k2, n = w.shape
    assert kdim == k2, (x.shape, w.shape)
    assert b.shape == (n,), (b.shape, n)
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, kdim)
    nk = kdim // bk
    out = pl.pallas_call(
        functools.partial(_mm_kernel, activation=activation, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)
    return out.astype(x.dtype)


def vmem_bytes(bm=DEF_BM, bn=DEF_BN, bk=DEF_BK, dtype_bytes=4) -> int:
    """Static VMEM footprint estimate for one grid step (for §Perf)."""
    return (bm * bk + bk * bn + bn) * dtype_bytes + bm * bn * 4


def mxu_utilization_estimate(m, n, k, bm=DEF_BM, bn=DEF_BN, bk=DEF_BK) -> float:
    """Fraction of MXU issue slots doing useful work for given shapes.

    The MXU is a 128x128 systolic array; tiles that are not multiples of
    128 waste lanes. This is the structural estimate recorded in §Perf.
    """
    bm, bn, bk = _pick(bm, m), _pick(bn, n), _pick(bk, k)
    eff = lambda b: min(b, 128) / 128.0
    return eff(bm) * eff(bn) * eff(bk)
